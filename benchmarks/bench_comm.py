import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Comm-layer microbenchmarks gating the fused-packet wire format.

Measures the hot configurations the fused single-packet format and the
batched >MTU segmentation engine exist for, and counts the
``collective-permute`` ops left in the compiled HLO of each program so
the collective budget is a *measured* number, not a belief:

* ``put_long`` acked, payload <= MTU      (1 fused packet + 1 reply)
* ``put_long`` acked, payload = 4 MTUs    (batched: 1 packet + 1 reply)
* ``put_long`` async, payload = 4 MTUs    (batched: 1 packet)
* ``get_medium``, payload = 4 MTUs        (1 request + 1 batched response)
* small-message throughput: 1024 4-word mailbox sends to one
  destination as ONE flushed packet stack (the actor layer) — the row
  reports µs per 1k sends; a companion ``mailbox/msgs-per-collective``
  row reports the aggregation ratio
* one full Jacobi iteration at grid 4096 / 8 kernels (the paper's
  footnote-2 failing configuration: halo row 4096 words > 2250-word MTU)
* the steady-state Jacobi loop with reply piggybacking: acks ride the
  next iteration's reverse-link data packet, so each iteration costs 2
  collectives instead of 4 — the row reports µs and collective-permutes
  *per iteration* (loop-exit ledger drains divided out)

CSV: ``name,us_per_call,collective_permutes``.

``BENCH_SMOKE=1`` trims iterations and skips the big Jacobi grid — the
fast pre-merge mode ``benchmarks/run.py --smoke`` drives to assert the
collective budgets without the full timing sweep.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.launch.hlo_analysis import parse_collectives
from repro.runtime import TCP, UDP
from repro.runtime.topology import make_cpu_mesh

from benchmarks._timing import time_fn

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]
SMOKE = os.environ.get("BENCH_SMOKE") == "1"
ITERS = 3 if SMOKE else 20


def cp_count(fn, *args) -> float:
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return parse_collectives(hlo).ops.get("collective-permute", 0.0)


def bench(name, fn, state0, iters=None):
    jitted = jax.jit(fn)
    us = time_fn(jitted, state0, iters=iters or ITERS)
    cps = cp_count(fn, state0)
    print(f"{name},{us:.1f},{cps:.0f}")


def main():
    mesh = make_cpu_mesh(N, ("kernel",))
    mtu_words = TCP.max_packet_words          # 2250 (9000-byte jumbo frame)
    seg_words = 4 * mtu_words + 64

    for transport, tname in ((TCP, "acked"), (UDP, "async")):
        ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=transport,
                           segment_words=seg_words)
        gas = GlobalAddressSpace(ctx)
        state0 = gas.make_global_state()

        def put1(st, ctx=ctx, transport=transport):
            pay = jnp.ones((mtu_words,), jnp.float32)
            return ops.put_long(ctx, st, pay, RING, dst_addr=0, token=1,
                                asynchronous=not transport.acked)

        bench(f"comm/put_long/{tname}/1seg", gas.spmd(put1), state0)

        def put4(st, ctx=ctx, transport=transport):
            pay = jnp.ones((4 * mtu_words,), jnp.float32)
            return ops.put_long(ctx, st, pay, RING, dst_addr=0, token=1,
                                asynchronous=not transport.acked)

        bench(f"comm/put_long/{tname}/4seg", gas.spmd(put4), state0)

    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=seg_words)
    gas = GlobalAddressSpace(ctx)
    state0 = gas.make_global_state()

    def get4(st):
        st, _ = ops.get_medium(ctx, st, RING, src_addr=0,
                               nwords=4 * mtu_words, token=2)
        return st

    bench("comm/get_medium/acked/4seg", gas.spmd(get4), state0)

    # small-message throughput: 1024 4-word sends to the ring neighbor
    # through one actor mailbox flush (vs 1024 collectives unbatched)
    n_msgs, w = 1024, 4

    def mailbox1k(st):
        mb = ctx.mailbox(RING, msg_words=w, watermark=1 << 20, token=5)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        st = mb.flush(st)
        return ops.wait_replies(ctx, st, token=5, n=1)

    fn_mb = gas.spmd(mailbox1k)
    us = time_fn(jax.jit(fn_mb), state0, iters=max(ITERS, 5), warmup=2)
    cps = cp_count(fn_mb, state0)
    print(f"comm/mailbox/1k-4word-sends,{us:.1f},{cps:.0f}")
    print(f"mailbox/msgs-per-collective,{n_msgs / max(cps, 1):.1f},"
          f"{cps:.0f} collectives for {n_msgs} sends")

    # steady-state Jacobi: halo puts defer their acks into the receiver
    # ledger and the acks piggyback home on the NEXT iteration's
    # reverse-link packet -> 2 CPs/iteration + 2 one-off loop-exit
    # drains.  Derived column is CPs per iteration (drains divided out).
    from repro.apps.jacobi import JacobiApp
    steady_n, steady_iters = (64 if SMOKE else 4096), 4
    app = JacobiApp(n=steady_n, kernels=N, iters=steady_iters)
    fn = app.build()
    gas_j = GlobalAddressSpace(app.ctx)
    st = gas_j.make_global_state()
    blocks = jnp.zeros((N, steady_n // N, steady_n), jnp.float32)
    us = time_fn(fn, st, blocks, iters=3 if SMOKE else 5, warmup=1)
    hlo = fn.lower(st, blocks).compile().as_text()
    cps = parse_collectives(hlo).ops.get("collective-permute", 0.0)
    print(f"comm/jacobi-steady/per-iter,{us / steady_iters:.1f},"
          f"{(cps - 2) / steady_iters:.0f}")

    if SMOKE:
        return

    # one Jacobi iteration, grid 4096 x 8 kernels: halo rows segment 2x
    app = JacobiApp(n=4096, kernels=N, iters=1)
    fn = app.build()
    gas_j = GlobalAddressSpace(app.ctx)
    st = gas_j.make_global_state()
    blocks = jnp.zeros((N, 4096 // N, 4096), jnp.float32)
    us = time_fn(fn, st, blocks, iters=5, warmup=2)
    hlo = fn.lower(st, blocks).compile().as_text()
    cps = parse_collectives(hlo).ops.get("collective-permute", 0.0)
    print(f"comm/jacobi-iter/4096x8,{us:.1f},{cps:.0f}")


if __name__ == "__main__":
    main()
