"""Benchmark harness: one module per paper table/figure.

  bench_latency      Fig. 4 (latency x topology, acked) + Fig. 5 (async speedup)
  bench_throughput   Fig. 6 (throughput x topology)
  bench_jacobi       Fig. 7 (kernels x grid) + Fig. 8 (multi-node spread)
  bench_utilization  Table I analogue (per-GAScore-stage + kernel costs)
  roofline           §Roofline generator (reads dryrun_results.jsonl)

Each module prints ``name,us_per_call,derived`` CSV rows;
``python -m benchmarks.run`` drives them all (comm benchmarks run in
subprocesses with an 8-device host platform to emulate a cluster).
"""
