import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Fig. 6: sustained throughput across topologies.

Measured: K back-to-back async Long puts per compiled call (pipelined,
no per-message reply wait — the paper's non-blocking case), payload
goodput in MB/s on the CPU host.  Derived: modeled TPU link goodput
(header overhead included).  Also compares the shoal ring all-reduce vs
the fused XLA all-reduce (the backend delta the trainer exposes).
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.core import collectives as coll
from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime import UDP, LinkClass, model_throughput_Bps
from repro.runtime.topology import make_mesh

from benchmarks._timing import time_fn

PAYLOAD_BYTES = [64, 512, 4096, 32768]
K = 16   # messages per call
N = 8


def main():
    mesh = make_mesh((2, 4), ("pod", "chip"))
    ctx = ShoalContext(mesh=mesh, axes=("pod", "chip"), transport=UDP,
                       segment_words=32768 // 4 + 8)
    gas = GlobalAddressSpace(ctx)
    state0 = gas.make_global_state()
    topos = [
        ("same-kernel", [(i, i) for i in range(N)], LinkClass.LOCAL),
        ("intra-pod", [(0, 1), (1, 2), (2, 3), (3, 0),
                       (4, 5), (5, 6), (6, 7), (7, 4)], LinkClass.ICI),
        ("inter-pod", [(i, (i + 4) % 8) for i in range(8)], LinkClass.DCN),
    ]
    for topo, pattern, link in topos:
        for pb in PAYLOAD_BYTES:
            nw = pb // 4

            def prog(st):
                pay = jnp.ones((nw,), jnp.float32)
                for t in range(K):
                    st = ops.put_long(ctx, st, pay, pattern, dst_addr=0,
                                      token=0, asynchronous=True)
                return st

            us = time_fn(jax.jit(gas.spmd(prog)), state0, iters=10)
            mbps = (K * pb) / (us / 1e6) / 1e6
            model_mbps = model_throughput_Bps(UDP, link, pb) / 1e6
            print(f"tput/long-async/{topo}/{pb}B,{us/K:.1f},{mbps:.1f}")
            print(f"tput/long-async-modelMBs/{topo}/{pb}B,0.0,{model_mbps:.1f}")

    # shoal ring vs fused XLA all-reduce (1 MB payload over all 8 kernels)
    x = jnp.ones((8, 32768), jnp.float32)
    ring = jax.jit(shard_map(
        lambda v: coll.ring_all_reduce(v, ("pod", "chip"), 8), mesh=mesh,
        in_specs=P(("pod", "chip")), out_specs=P(("pod", "chip"))))
    fused = jax.jit(shard_map(
        lambda v: jax.lax.psum(v, ("pod", "chip")), mesh=mesh,
        in_specs=P(("pod", "chip")), out_specs=P(("pod", "chip"))))
    us_ring = time_fn(ring, x, iters=10)
    us_fused = time_fn(fused, x, iters=10)
    print(f"allreduce/shoal-ring/1MB,{us_ring:.1f},{131072/us_ring:.1f}")
    print(f"allreduce/xla-fused/1MB,{us_fused:.1f},{131072/us_fused:.1f}")


if __name__ == "__main__":
    main()
