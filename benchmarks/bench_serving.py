import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")

"""Disaggregated-serving benchmark: sustained decode under a mixed
prefill/decode arrival trace.

Topology: 2 prefill kernels + 2 decode kernels (2 lanes each) on one
kernel mesh.  A deterministic arrival trace feeds the admission
front-end (bounded queue, REJECTED jobs retried on later ticks — the
backpressure path is part of what is measured); every admitted request
is prefilled on a prefill kernel and its KV migrated to a decode lane
as ONE ``put_long_vectored`` into the decode kernel's PGAS segment.

CSV rows (``name,value,derived``):

* ``serving/mixed-trace/tokens-per-s`` — sustained generated tokens/s
  over the whole trace (admission + prefill + migration + decode);
* ``serving/mixed-trace/peak-queue-depth`` — observed admission-queue
  high-water mark, with the configured bound in the derived column;
* ``comm/kv-migrate/vectored-lane`` — µs per compiled KV-migration call
  and its HLO collective-permute count (must be 2: one fused vectored
  packet + one coalesced reply).

``BENCH_SMOKE=1`` trims the trace.  Driven by
``benchmarks/run.py --serving``, which asserts the budgets and merges
the rows into ``BENCH_comm.json``.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import ServingSlices
from repro.models.model import ModelConfig, build_model
from repro.serving import DONE, REJECTED, ServeFrontend
from repro.serving.disagg import DisaggServeTier
from repro.serving.engine import lane_slice

from benchmarks._timing import time_fn

SMOKE = os.environ.get("BENCH_SMOKE") == "1"
N_REQ = 6 if SMOKE else 24
MAX_QUEUE = 8
SLOTS = 16

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   dtype=jnp.float32)


def make_trace(n):
    """Deterministic mixed-arrival trace: (tick_arrivals, prompts)."""
    rng = np.random.default_rng(0)
    reqs = [(list(rng.integers(1, TINY.vocab,
                               size=int(rng.integers(2, 7)))),
             int(rng.integers(3, 7)))
            for _ in range(n)]
    return rng, reqs


def drive_trace(fe, reqs, rng):
    """Feed the trace through the front-end; rejected submissions retry
    on a later tick (the backpressure contract at work)."""
    pending = list(reqs)
    done_jobs = []
    t0 = time.perf_counter()
    while pending or fe.pump():
        for _ in range(int(rng.integers(0, 3))):
            if not pending:
                break
            prompt, max_new = pending[0]
            job = fe.submit(prompt, max_new)
            if job.status == REJECTED:
                break               # queue full: retry this tick's rest later
            pending.pop(0)
            done_jobs.append(job)
        fe.pump()
    elapsed = time.perf_counter() - t0
    return done_jobs, elapsed


def main():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    slices = ServingSlices(n_prefill=2, n_decode=2)
    tier = DisaggServeTier(model, params, slices, lanes_per_decode=2,
                           slots=SLOTS)
    fe = ServeFrontend(tier, max_queue=MAX_QUEUE)

    rng, reqs = make_trace(N_REQ)
    # warm the compile caches (all prefill lengths, decode, migrations)
    # so the timed trace measures serving, not XLA compiles
    warm, _ = drive_trace(ServeFrontend(tier, max_queue=MAX_QUEUE),
                          reqs, np.random.default_rng(1))
    assert all(j.status == DONE for j in warm)

    jobs, elapsed = drive_trace(fe, reqs, rng)
    assert all(j.status == DONE for j in jobs), "trace left unfinished jobs"
    tokens = sum(len(j.tokens) for j in jobs)
    print(f"serving/mixed-trace/tokens-per-s,{tokens / elapsed:.1f},"
          f"{len(jobs)} reqs {tokens} tokens in {elapsed:.2f}s")
    print(f"serving/mixed-trace/peak-queue-depth,{fe.peak_queue_depth:.0f},"
          f"bound={MAX_QUEUE}")

    # one KV migration: µs per call + the HLO collective budget
    src, dst = 0, slices.decode_ids[0]
    blocks = tuple(tier.kv.pack_lane(
        lane_slice(tier.workers[src]._cache0, 0)))
    fn = tier._migration(src, dst, 0)
    us = time_fn(fn, tier.state, blocks, iters=3 if SMOKE else 20, warmup=2)
    hlo = tier.migration_hlo(src, dst, 0)
    cps = parse_collectives(hlo).ops.get("collective-permute", 0.0)
    print(f"comm/kv-migrate/vectored-lane,{us:.1f},{cps:.0f}")


if __name__ == "__main__":
    main()
