"""Shared timing helper."""

import time

import jax


def time_fn(fn, *args, iters: int = 20, warmup: int = 3):
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
