import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Figs. 4 & 5: AM latency across placement topologies.

Measured: wall-time per AM on the emulated 8-kernel CPU cluster (mesh
(2, 4) = 2 "pods" x 4 chips), per AM class x payload x topology, for
acked (TCP-analogue) and async (UDP-analogue) transports, plus the
HUMboldt two-sided baseline.  Derived column: modeled TPU-target latency
from the transport link model (ICI/DCN), which is what the paper's
absolute numbers correspond to.

Reproduced qualitative claims: one-sided < two-sided; async < acked
(Fig. 5's UDP speedup); LOCAL < ICI < DCN; latency grows with payload
above a constant floor.
"""

import jax
import jax.numpy as jnp

from repro.core import handlers as hd
from repro.core import humboldt, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime import TCP, UDP, LinkClass, model_latency_s
from repro.runtime.topology import make_mesh

from benchmarks._timing import time_fn

PAYLOAD_BYTES = [8, 64, 512, 4096]
N = 8


def patterns():
    # (name, pattern, link class) on the (2,4) pod mesh: kernels 0-3 pod0
    return [
        ("same-kernel", [(i, i) for i in range(N)], LinkClass.LOCAL),
        ("intra-pod", [(0, 1), (1, 2), (2, 3), (3, 0),
                       (4, 5), (5, 6), (6, 7), (7, 4)], LinkClass.ICI),
        ("inter-pod", [(i, (i + 4) % 8) for i in range(8)], LinkClass.DCN),
    ]


def main():
    mesh = make_mesh((2, 4), ("pod", "chip"))
    rows = []
    for transport, tname in ((TCP, "acked"), (UDP, "async")):
        ctx = ShoalContext(mesh=mesh, axes=("pod", "chip"),
                           transport=transport, segment_words=4096)
        gas = GlobalAddressSpace(ctx)
        state0 = gas.make_global_state()
        for topo, pattern, link in patterns():
            for pb in PAYLOAD_BYTES:
                nw = pb // 4

                def prog_long(st):
                    pay = jnp.ones((nw,), jnp.float32)
                    st = ops.put_long(ctx, st, pay, pattern, dst_addr=0,
                                      token=1,
                                      asynchronous=not transport.acked)
                    return st

                fn = jax.jit(gas.spmd(prog_long))
                us = time_fn(fn, state0)
                model_us = model_latency_s(transport, link, pb) * 1e6
                rows.append((f"lat/long/{tname}/{topo}/{pb}B", us, model_us))

            # header-only short AM
            def prog_short(st):
                return ops.put_short(ctx, st, pattern, token=1,
                                     asynchronous=not transport.acked)

            us = time_fn(jax.jit(gas.spmd(prog_short)), state0)
            model_us = model_latency_s(transport, link, 0) * 1e6
            rows.append((f"lat/short/{tname}/{topo}/0B", us, model_us))

            # medium AM
            def prog_med(st):
                pay = jnp.ones((128,), jnp.float32)
                st, _ = ops.put_medium(ctx, st, pay, pattern, token=1,
                                       asynchronous=not transport.acked)
                return st

            us = time_fn(jax.jit(gas.spmd(prog_med)), state0)
            model_us = model_latency_s(transport, link, 512) * 1e6
            rows.append((f"lat/medium/{tname}/{topo}/512B", us, model_us))

    # HUMboldt two-sided baseline (Fig. 4 context; 4 link traversals)
    ctx = ShoalContext(mesh=mesh, axes=("pod", "chip"), transport=TCP,
                       segment_words=4096)
    gas = GlobalAddressSpace(ctx)
    state0 = gas.make_global_state()
    for topo, pattern, link in patterns():
        for pb in [8, 512, 4096]:
            nw = pb // 4

            def prog_h(st):
                st, _ = humboldt.sendrecv(ctx, st, jnp.ones((nw,), jnp.float32),
                                          pattern, token=1)
                return st

            us = time_fn(jax.jit(gas.spmd(prog_h)), state0)
            model_us = model_latency_s(TCP, link, pb,
                                       hops=humboldt.HOPS_PER_MESSAGE) * 1e6
            rows.append((f"lat/humboldt/two-sided/{topo}/{pb}B", us, model_us))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3f}")

    # Fig. 5 analogue: async speedup over acked, per topology (modeled)
    for topo, _, link in patterns():
        for pb in PAYLOAD_BYTES:
            s = (model_latency_s(TCP, link, pb)
                 / model_latency_s(UDP, link, pb))
            print(f"speedup/async-vs-acked/{topo}/{pb}B,0.0,{s:.3f}")


if __name__ == "__main__":
    main()
