"""§Roofline generator: three roofline terms per (arch x shape x mesh).

Reads the dry-run JSONL (launch/dryrun.py) and computes, per cell:

  compute term    = HLO_FLOPs / (chips * peak)         [s]
  memory term     = HLO_bytes / (chips * HBM bw)       [s]
  collective term = wire_bytes / (links * link bw)     [s]

Constants: TPU-v5e-class 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (25 GB/s assumed for the DCN pod axis).  cost_analysis numbers are
already per-device (SPMD-partitioned); `dot_flops_weighted` is the
trip-count-corrected matmul FLOP count parsed from the optimized HLO
(XLA's cost analysis counts while bodies once — see
launch/hlo_analysis.py), and we take max(raw, weighted).

MODEL_FLOPS = 6*N*D for training (N = active non-embedding params, D =
tokens/step) or 2*N*B per decoded-token batch; the ratio against
compiled FLOPs exposes remat/dispatch waste.
"""

from __future__ import annotations

import json
import sys

from repro import configs

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9


def active_params(cfg) -> float:
    """Analytic non-embedding *active* param count (MoE: top-k+shared)."""
    d, L = cfg.d_model, cfg.n_layers
    dh = cfg.dh
    H, K = cfg.n_heads, cfg.n_kv_heads
    per_layer = 0.0
    if cfg.family == "ssm":
        pd = int(d * cfg.mlstm_pf)
        mlstm = d * pd * 2 + 3 * pd * pd + pd * d
        slstm = d * 4 * d + (d // cfg.n_heads) * 4 * (d // cfg.n_heads) * cfg.n_heads \
            + 2 * d * int(d * 4 / 3) + int(d * 4 / 3) * d
        n_s = L // cfg.slstm_every if cfg.slstm_every else 0
        return (L - n_s) * mlstm + n_s * slstm
    if cfg.mla:
        m = cfg.mla
        attn = (d * m.q_lora + m.q_lora * H * (m.dh_nope + m.dh_rope)
                + d * m.kv_lora + d * m.dh_rope
                + m.kv_lora * H * (m.dh_nope + m.dh_v) + H * m.dh_v * d)
    else:
        attn = d * H * dh + 2 * d * K * dh + H * dh * d
    if cfg.moe:
        ff = 3 * d * cfg.moe.d_ff_expert * (cfg.moe.top_k + cfg.moe.n_shared)
        dense_ff = 3 * d * cfg.d_ff
        n_moe = L - cfg.first_k_dense
        per = attn + ff
        return n_moe * per + cfg.first_k_dense * (attn + dense_ff)
    if cfg.family == "hybrid":
        dr = cfg.dr
        nb = cfg.n_heads
        rglru = 2 * d * dr + 2 * nb * (dr // nb) ** 2 + dr * d + 3 * d * cfg.d_ff
        attn_l = attn + 3 * d * cfg.d_ff
        n_attn = sum(1 for i in range(L) if i % 3 == 2)
        return (L - n_attn) * rglru + n_attn * attn_l
    mlp = (2 if cfg.mlp == "gelu" else 3) * d * cfg.d_ff
    return L * (attn + mlp)


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = configs.full(rec["arch"])
    pd = rec["per_device"]
    mesh = rec["mesh"]
    chips = 1
    for v in mesh.values():
        chips *= v
    flops_dev = max(pd["flops"], pd.get("dot_flops_weighted", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = pd["bytes_accessed"] / HBM_BW
    link_bw = DCN_BW if rec["multi_pod"] else ICI_BW
    t_coll = pd["collective_wire_bytes"] / link_bw

    shape = rec["shape"]
    from repro.configs.shapes import SHAPES
    sp = SHAPES[shape]
    n_active = active_params(cfg)
    if sp.mode == "train":
        model_flops = 6 * n_active * sp.seq_len * sp.global_batch
    elif sp.mode == "prefill":
        model_flops = 2 * n_active * sp.seq_len * sp.global_batch
    else:
        model_flops = 2 * n_active * sp.global_batch
    model_flops_dev = model_flops / chips
    useful = model_flops_dev / flops_dev if flops_dev else 0.0

    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])
    total = max(t_compute, t_memory, t_coll)
    frac = (model_flops_dev / PEAK_FLOPS) / total if total else 0.0
    return {
        "arch": rec["arch"], "shape": shape,
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "backend": rec.get("backend", "xla"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant[0],
        "bound_s": total,
        "model_flops_dev": model_flops_dev, "hlo_flops_dev": flops_dev,
        "useful_ratio": useful, "roofline_frac": frac,
        "peak_gb": pd["peak_bytes"] / 1e9,
        "fits_16gb": pd["peak_bytes"] < 16e9,
    }


def main(path="dryrun_results.jsonl"):
    recs = [json.loads(l) for l in open(path)]
    seen = {}
    for r in recs:   # last record wins (re-runs override)
        key = (r["arch"], r["shape"], r["multi_pod"], r.get("backend", "xla"))
        seen[key] = r
    out = []
    for r in seen.values():
        a = analyze(r)
        if a:
            out.append(a)
    out.sort(key=lambda a: (a["arch"], a["shape"], a["mesh"]))
    for a in out:
        print(f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}"
              f",{a['bound_s']*1e6:.1f}"
              f",dom={a['dominant']};tc={a['t_compute_s']*1e3:.2f}ms"
              f";tm={a['t_memory_s']*1e3:.2f}ms"
              f";tx={a['t_collective_s']*1e3:.2f}ms"
              f";useful={a['useful_ratio']:.2f}"
              f";frac={a['roofline_frac']:.3f}"
              f";mem={a['peak_gb']:.1f}GB")
    return out


if __name__ == "__main__":
    main(*sys.argv[1:])
