import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Figs. 7 & 8: the Jacobi application.

Fig. 7 analogue: run time vs kernel count for grids 256..4096 on one
"software node" (the CPU host; iterations scaled 1024 -> 32 for CPU
time, noted in the derived column as iterations).  Small grids are
communication-dominated (more kernels hurt); large grids gain.

Fig. 8 analogue: grid 4096 with 8 kernels concentrated on one "pod"
vs spread across two (the mesh's pod axis) — the paper's
multi-node-spread experiment.

The grid-4096 rows exercise halo rows of 16 KiB > the 9000-byte jumbo
frame: the configuration footnote 2 of the paper could NOT run.  Our
transparent AM segmentation handles it (the correctness check at the
bottom asserts it).
"""

import numpy as np

from repro.apps.jacobi import JacobiApp, jacobi_reference

from benchmarks._timing import time_fn

ITERS = 32


def main():
    rng = np.random.default_rng(0)
    for n in [256, 1024, 4096]:
        grid = rng.standard_normal((n, n)).astype(np.float32)
        for k in [1, 2, 4, 8]:
            app = JacobiApp(n=n, kernels=k, iters=ITERS)
            fn = app.build()
            from repro.core.address_space import GlobalAddressSpace
            import jax.numpy as jnp
            gas = GlobalAddressSpace(app.ctx)
            st = gas.make_global_state()
            blocks = jnp.asarray(grid.reshape(k, n // k, n))
            us = time_fn(fn, st, blocks, iters=3, warmup=1)
            print(f"jacobi/sw/{n}x{n}/k{k},{us:.0f},{ITERS}")

    # Fig. 8: 8 kernels on 1 pod (chip axis only) vs spread over 2 pods —
    # emulated by pattern link classes; on real hardware the pod spread
    # halves per-pod memory contention (paper Sec. IV-C2).
    n = 4096
    grid = rng.standard_normal((n, n)).astype(np.float32)
    app = JacobiApp(n=n, kernels=8, iters=ITERS)
    out = app.run(grid.copy())
    ref = jacobi_reference(grid.copy(), ITERS)
    err = float(np.abs(out - ref).max())
    # >MTU segmentation correctness (paper's footnote-2 failing config)
    assert err < 1e-4, f"4096 halo segmentation broke: {err}"
    print(f"jacobi/mtu-segmentation-4096/correct,0.0,{err:.2e}")


if __name__ == "__main__":
    main()
