"""Table I analogue: per-component cost of the GAScore datapath.

The paper reports LUT/FF/BRAM per GAScore stage.  The TPU-native
equivalents of "hardware cost" are compiled FLOPs, bytes accessed, and
the kernels' VMEM working sets — extracted per stage from
``jit(stage).lower().compile().cost_analysis()``.  Runs on the single
real CPU device (the stages are per-kernel datapaths).
"""

import jax
import jax.numpy as jnp

from repro.core import am, gascore as gc, handlers as hd
from repro.core.state import PgasState, ShoalContext
from repro.runtime.topology import make_cpu_mesh

PKT = 1024  # words per packet for the costing


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis() or {}
    if isinstance(c, (list, tuple)):   # older jax: one dict per program
        c = c[0] if c else {}
    return c.get("flops", 0.0), c.get("bytes accessed", 0.0)


def main():
    mesh = make_cpu_mesh(1, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), segment_words=8192)
    st = PgasState.make(8192)
    # headers travel as raw int32 vectors; decode inside the jitted stage
    hdr_long = am.encode(type=am.make_type(am.LONG), nwords=PKT,
                         dst_addr=64, handler=hd.H_ADD)
    hdr_med = am.encode(type=am.make_type(am.MEDIUM), nwords=PKT)
    hdr_short = am.encode(type=am.make_type(am.SHORT), handler=hd.H_ADD,
                          token=1)
    hdr_get = am.encode(type=am.make_type(am.MEDIUM, get=True), nwords=PKT,
                        src_addr=0)
    pay = jnp.ones((PKT,), jnp.float32)

    rows = [
        ("gascore/am_rx+xpams_rx (ingress_long)",
         *_cost(lambda s, h, p: gc.ingress_long(ctx, s, am.decode(h), p, PKT),
                st, hdr_long, pay)),
        ("gascore/xpams_rx->kernels (ingress_medium)",
         *_cost(lambda s, h, p: gc.ingress_medium(s, am.decode(h), p, PKT),
                st, hdr_med, pay)),
        ("gascore/handler-wrapper (ingress_short)",
         *_cost(lambda s, h: gc.ingress_short(ctx, s, am.decode(h)),
                st, hdr_short)),
        ("gascore/datamover-read (egress mem)",
         *_cost(lambda s, h: gc.egress(ctx, s, am.decode(h), None, PKT),
                st, hdr_long)),
        ("gascore/get-responder (serve_get)",
         *_cost(lambda s, h: gc.serve_get(ctx, s, am.decode(h), PKT),
                st, hdr_get)),
        ("gascore/reply (ingress_reply)",
         *_cost(lambda s, h: gc.ingress_reply(s, am.decode(h)),
                st, hdr_short)),
    ]
    for name, flops, byts in rows:
        print(f"{name},0.0,flops={flops:.0f};bytes={byts:.0f}")

    # kernel VMEM working sets (the BRAM analogue)
    vmem = [
        ("kernels/jacobi 256x2048 f32 band x4", 4 * 256 * 2048 * 4),
        ("kernels/flash_attn BQ=BK=512 dh=128 f32", (2 * 512 * 128 * 4
                                                     + 2 * 512 * 128 * 4
                                                     + 512 * 4 * 2)),
        ("kernels/am_pack 8192-word segment f32", 8192 * 4),
    ]
    for name, b in vmem:
        print(f"{name},0.0,vmem_bytes={b}")


if __name__ == "__main__":
    main()
