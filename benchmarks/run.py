"""Drive all benchmarks; print ``name,us_per_call,derived`` CSV.

Comm/Jacobi benchmarks need a multi-device host platform, so each runs
in its own subprocess with XLA_FLAGS=...device_count=8 (the main process
keeps the single real device, and the production 512-device mesh exists
only inside dry-run processes).  The roofline section is only emitted if
a dry-run results file exists.
"""

import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

SUBPROCESS_BENCHES = [
    ("benchmarks.bench_latency", 8),
    ("benchmarks.bench_throughput", 8),
    ("benchmarks.bench_jacobi", 8),
]
INPROCESS_BENCHES = ["benchmarks.bench_utilization"]


def run_sub(mod: str, devices: int) -> int:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    proc = subprocess.run([sys.executable, "-m", mod], env=env,
                          capture_output=True, text=True, cwd=REPO)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stdout.write(f"{mod},FAILED,rc={proc.returncode}\n")
        sys.stderr.write(proc.stderr[-2000:] + "\n")
    return proc.returncode


def main() -> None:
    print("name,us_per_call,derived")
    rc = 0
    for mod, devs in SUBPROCESS_BENCHES:
        rc |= run_sub(mod, devs)
    for mod in INPROCESS_BENCHES:
        rc |= run_sub(mod, 1)
    results = os.path.join(REPO, "dryrun_results.jsonl")
    if os.path.exists(results):
        rc |= run_sub("benchmarks.roofline", 1)
    else:
        print("roofline,SKIPPED,no dryrun_results.jsonl (run "
              "scripts/run_dryrun_sweep.sh)")
    if rc:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
