"""Drive all benchmarks; print ``name,us_per_call,derived`` CSV and
write machine-readable ``BENCH_comm.json`` next to the repo root.

Comm/Jacobi benchmarks need a multi-device host platform, so each runs
in its own subprocess with XLA_FLAGS=...device_count=8 (the main process
keeps the single real device, and the production 512-device mesh exists
only inside dry-run processes).  The roofline section is only emitted if
a dry-run results file exists.

``BENCH_comm.json`` is the perf trajectory across PRs: for every bench
the measured ``us_per_call``, and for the comm-layer benches
(``benchmarks/bench_comm.py``) additionally the ``collective-permute``
count parsed out of the compiled HLO.  The
``baseline_pre_fused_wire`` section is frozen — it records the
measurements taken immediately *before* the fused single-packet wire
format landed — while ``current`` is overwritten by every run, so any
future regression is visible as a diff against both.

``--smoke`` is the fast pre-merge mode driven by ``scripts/ci_check.sh``:
it runs only ``bench_comm`` (with ``BENCH_SMOKE=1``, few timing iters,
no big Jacobi grid), asserts every comm row's collective-permute budget
including the mailbox messages-per-collective floor, then runs
``scripts/comm_lint.py`` (shoal-lint, both passes) over every
registered entry point — failing on any finding — and merges the
analyzer wall-time + HLO budget table into ``BENCH_comm.json`` under
``current.comm_lint`` (the comm/benches/baseline sections are left
untouched).

``--faults`` is the loss-resilience mode: it runs
``bench_faults`` (the 0/1/5%-drop goodput sweep over the reliable-put
protocol), asserts every drop rate still delivers bit-identical data
with a drained dedup ledger, gates the 1%-drop retransmit cost and
goodput against the ``[faults]`` section of ``comm_budgets.toml``, and
merges the rows into ``BENCH_comm.json`` under ``current.faults``.

``--serving`` is the disaggregated-serving smoke mode: it runs
``bench_serving`` (mixed prefill/decode arrival trace through the
admission front-end), asserts the KV-migration collective budget, the
bounded admission-queue depth and a nonzero sustained tokens/s, and
merges the rows into ``BENCH_comm.json`` under ``current.serving``.
"""

import json
import os
import re
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
BENCH_JSON = os.path.join(REPO, "BENCH_comm.json")

SUBPROCESS_BENCHES = [
    ("benchmarks.bench_comm", 8),
    ("benchmarks.bench_latency", 8),
    ("benchmarks.bench_throughput", 8),
    ("benchmarks.bench_jacobi", 8),
]
INPROCESS_BENCHES = ["benchmarks.bench_utilization"]

_ROW_RE = re.compile(r"^([\w/.+-]+),(-?[\d.]+),(.*)$")


def run_sub(mod: str, devices: int, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    if extra_env:
        env.update(extra_env)
    proc = subprocess.run([sys.executable, "-m", mod], env=env,
                          capture_output=True, text=True, cwd=REPO)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stdout.write(f"{mod},FAILED,rc={proc.returncode}\n")
        sys.stderr.write(proc.stderr[-2000:] + "\n")
    return proc.returncode, proc.stdout


def parse_rows(stdout: str):
    rows = []
    for line in stdout.splitlines():
        m = _ROW_RE.match(line.strip())
        if m:
            rows.append((m.group(1), float(m.group(2)), m.group(3)))
    return rows


def write_bench_json(rows) -> None:
    """Merge this run into BENCH_comm.json, preserving the frozen
    pre-fused-wire baseline section.

    Merge means MERGE: rows update ``current.comm``/``current.benches``
    key-by-key and every other ``current`` sub-section (``comm_lint``,
    ``serving``) is left alone — a partial run must not wipe sections it
    did not produce (that was exactly the stray-diff noise of PR 7's
    bench-only commit).  Key order is canonicalized by ``sort_keys`` so
    reruns with identical numbers are byte-identical.
    """
    doc = {"schema": "bench_comm/v1"}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            sys.stderr.write(
                f"WARNING: existing {BENCH_JSON} unreadable ({e}); "
                "restore it from git or the frozen pre-fused-wire "
                "baseline will be re-seeded from THIS run's numbers\n")
    comm, benches = {}, {}
    for name, us, derived in rows:
        if name.startswith("comm/"):
            # bench_comm's derived column is the HLO collective-permute
            # count of the compiled program
            comm[name] = {"us_per_call": us,
                          "collective_permutes": float(derived)}
        else:
            benches[name] = {"us_per_call": us, "derived": derived}
    cur = doc.setdefault("current", {})
    cur.setdefault("comm", {}).update(comm)
    cur.setdefault("benches", {}).update(benches)
    if "baseline_pre_fused_wire" not in doc:
        sys.stderr.write(
            "WARNING: BENCH_comm.json had no baseline_pre_fused_wire "
            "section; seeding it from this (post-fused-wire) run. The "
            "true pre-change numbers live in git history.\n")
        doc["baseline_pre_fused_wire"] = comm
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.relpath(BENCH_JSON, REPO)} "
          f"({len(comm)} comm rows, {len(benches)} bench rows)")


# collective-permute ceilings per comm bench row: the measured HLO count
# must not exceed these, or the fused-wire / mailbox aggregation has
# regressed.  (floor) rows assert the value is AT LEAST the budget.
SMOKE_BUDGETS = {
    "comm/put_long/acked/1seg": 2.0,
    "comm/put_long/acked/4seg": 2.0,
    "comm/put_long/async/1seg": 1.0,
    "comm/put_long/async/4seg": 1.0,
    "comm/get_medium/acked/4seg": 2.0,
    "comm/mailbox/1k-4word-sends": 2.0,
    # the one-collective-steady-state gate: data packets only, acks
    # piggybacked on the next iteration's reverse-link packet
    "comm/jacobi-steady/per-iter": 2.0,
}
SMOKE_FLOORS = {
    "mailbox/msgs-per-collective": 512.0,
}


def smoke() -> None:
    print("name,us_per_call,derived")
    code, out = run_sub("benchmarks.bench_comm", 8,
                        extra_env={"BENCH_SMOKE": "1"})
    if code:
        raise SystemExit(f"bench_comm failed (rc={code})")
    rows = {name: (us, derived) for name, us, derived in parse_rows(out)}
    failures = []
    for name, budget in SMOKE_BUDGETS.items():
        if name not in rows:
            failures.append(f"{name}: row missing from bench output")
            continue
        us, derived = rows[name]
        cps = float(derived.split()[0]) if derived else float("nan")
        if not cps <= budget:
            failures.append(f"{name}: {cps:.0f} collective-permutes "
                            f"> budget {budget:.0f}")
    for name, floor in SMOKE_FLOORS.items():
        if name not in rows:
            failures.append(f"{name}: row missing from bench output")
            continue
        us, _ = rows[name]
        if not us >= floor:
            failures.append(f"{name}: {us:.1f} < floor {floor:.1f}")
    if failures:
        for f in failures:
            print(f"SMOKE_FAIL {f}")
        raise SystemExit(1)
    lint = run_comm_lint()
    print(f"SMOKE_OK ({len(SMOKE_BUDGETS)} collective budgets, "
          f"{len(SMOKE_FLOORS)} aggregation floors, "
          f"{len(lint['entries'])} lint entries in "
          f"{lint['total_wall_time_s']:.1f}s)")


def run_comm_lint() -> dict:
    """Run scripts/comm_lint.py (both analyzer passes over every
    registered entry point) in a subprocess, fail the smoke on findings,
    and merge the analyzer wall-time + HLO budget table into
    BENCH_comm.json under ``current.comm_lint`` (other sections and the
    frozen baseline are left untouched)."""
    import subprocess
    import tempfile

    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        path = tmp.name
    try:
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts", "comm_lint.py"),
             "--json", path],
            capture_output=True, text=True, timeout=900)
        sys.stdout.write(proc.stdout)
        if proc.returncode:
            sys.stderr.write(proc.stderr[-4000:])
            raise SystemExit(
                f"SMOKE_FAIL shoal-lint found issues (rc={proc.returncode})")
        with open(path) as f:
            lint = json.load(f)
    finally:
        os.unlink(path)
    # Wall-clock times vary run to run; keep them out of the committed
    # JSON so a re-run with identical analyzer results diffs clean.  The
    # full doc (times included) is still returned for the SMOKE_OK line.
    stable = {"entries": {
        name: {k: v for k, v in entry.items() if k != "wall_time_s"}
        for name, entry in lint.get("entries", {}).items()}}
    doc = {"schema": "bench_comm/v1"}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc.setdefault("current", {})["comm_lint"] = stable
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return lint


# --serving gates: the KV migration's collective budget (1 fused
# vectored packet + 1 coalesced reply) and the admission bound
SERVING_CP_BUDGETS = {
    "comm/kv-migrate/vectored-lane": 2.0,
}


def serving() -> None:
    """Disaggregated-serving smoke: run the mixed-arrival trace bench,
    assert the migration collective budget / bounded queue depth /
    nonzero sustained throughput, and merge the rows into
    BENCH_comm.json under ``current.serving`` (the comm/benches/baseline
    sections are left untouched)."""
    print("name,us_per_call,derived")
    code, out = run_sub("benchmarks.bench_serving", 4,
                        extra_env={"BENCH_SMOKE": "1"})
    if code:
        raise SystemExit(f"bench_serving failed (rc={code})")
    rows = {name: (us, derived) for name, us, derived in parse_rows(out)}
    failures = []
    for name, budget in SERVING_CP_BUDGETS.items():
        if name not in rows:
            failures.append(f"{name}: row missing from bench output")
            continue
        cps = float(rows[name][1].split()[0])
        if not cps <= budget:
            failures.append(f"{name}: {cps:.0f} collective-permutes "
                            f"> budget {budget:.0f}")
    tps = rows.get("serving/mixed-trace/tokens-per-s")
    if tps is None:
        failures.append("serving/mixed-trace/tokens-per-s: row missing")
    elif not tps[0] > 0:
        failures.append(f"tokens-per-s: {tps[0]} not > 0")
    depth = rows.get("serving/mixed-trace/peak-queue-depth")
    if depth is None:
        failures.append("serving/mixed-trace/peak-queue-depth: row missing")
    else:
        bound = float(depth[1].split("=")[1])
        if not depth[0] <= bound:
            failures.append(f"peak-queue-depth: {depth[0]:.0f} "
                            f"> admission bound {bound:.0f}")
    if failures:
        for f in failures:
            print(f"SERVING_FAIL {f}")
        raise SystemExit(1)
    doc = {"schema": "bench_comm/v1"}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc.setdefault("current", {})["serving"] = {
        name: {"value": us, "derived": derived}
        for name, (us, derived) in rows.items()}
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"SERVING_OK ({len(rows)} rows merged into "
          f"{os.path.relpath(BENCH_JSON, REPO)})")


def _load_fault_budgets() -> dict:
    """The [faults] section of comm_budgets.toml (gates for --faults)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.analysis.hlo_budget import load_budgets
    return load_budgets().get("faults", {})


def faults() -> None:
    """Loss-resilience smoke: run the 0/1/5%-drop goodput sweep, assert
    delivery stayed correct at every rate, gate the 1%-drop retransmit
    cost + goodput against comm_budgets.toml [faults], and merge the
    rows into BENCH_comm.json under ``current.faults`` (other sections
    and the frozen baseline are left untouched)."""
    print("name,value,derived")
    code, out = run_sub("benchmarks.bench_faults", 8)
    if code:
        raise SystemExit(f"bench_faults failed (rc={code})")
    rows = {name: (us, derived) for name, us, derived in parse_rows(out)}
    budgets = _load_fault_budgets()
    failures = []
    for pct in ("0pct", "1pct", "5pct"):
        ok = rows.get(f"faults/delivered-ok/{pct}")
        if ok is None:
            failures.append(f"faults/delivered-ok/{pct}: row missing")
        elif ok[0] != 1.0:
            failures.append(
                f"faults/delivered-ok/{pct}: delivery broke under loss "
                "(not bit-identical / ledger not drained / retries "
                "exhausted)")
    rounds = rows.get("faults/retransmit-rounds/1pct")
    cap = float(budgets.get("retransmit_rounds_at_1pct_max", 0.5))
    if rounds is None:
        failures.append("faults/retransmit-rounds/1pct: row missing")
    elif not rounds[0] <= cap:
        failures.append(f"retransmit-rounds at 1%: {rounds[0]:.3f} "
                        f"> budget {cap}")
    good = rows.get("faults/goodput/1pct")
    floor = float(budgets.get("goodput_at_1pct_min", 0.0))
    if good is None:
        failures.append("faults/goodput/1pct: row missing")
    elif not good[0] >= floor:
        failures.append(f"goodput at 1%: {good[0]:.3f} < floor {floor}")
    if failures:
        for f in failures:
            print(f"FAULTS_FAIL {f}")
        raise SystemExit(1)
    doc = {"schema": "bench_comm/v1"}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            doc = json.load(f)
    doc.setdefault("current", {})["faults"] = {
        name: {"value": us, "derived": derived}
        for name, (us, derived) in rows.items()}
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"FAULTS_OK ({len(rows)} rows merged into "
          f"{os.path.relpath(BENCH_JSON, REPO)}; retransmit-rounds "
          f"{rounds[0]:.3f} <= {cap}, goodput {good[0]:.3f} >= {floor})")


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        smoke()
        return
    if "--faults" in sys.argv[1:]:
        faults()
        return
    if "--serving" in sys.argv[1:]:
        serving()
        return
    print("name,us_per_call,derived")
    rc = 0
    rows = []
    for mod, devs in SUBPROCESS_BENCHES:
        code, out = run_sub(mod, devs)
        rc |= code
        rows.extend(parse_rows(out))
    for mod in INPROCESS_BENCHES:
        code, out = run_sub(mod, 1)
        rc |= code
        rows.extend(parse_rows(out))
    results = os.path.join(REPO, "dryrun_results.jsonl")
    if os.path.exists(results):
        code, out = run_sub("benchmarks.roofline", 1)
        rc |= code
        rows.extend(parse_rows(out))
    else:
        print("roofline,SKIPPED,no dryrun_results.jsonl (run "
              "scripts/run_dryrun_sweep.sh)")
    write_bench_json(rows)
    if rc:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
