import os

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Goodput-under-loss sweep for the lossy-transport reliability stack.

Runs the same acked 4-segment ``put_long`` (RING, 8 kernels, small MTU)
over a :class:`~repro.runtime.LossyTransport` at 0%, 1% and 5% injected
drop, and reports per drop rate:

* ``faults/goodput/<p>pct``     — delivered payload words / total wire
  words actually transmitted (NOP rounds after delivery cost nothing,
  so this is the *dynamic* efficiency under loss, not a static count)
* ``faults/retransmit-rounds/<p>pct`` — mean per-kernel ``retransmits``
  counter: how many retry rounds senders really re-sent in
* ``faults/delivered-ok/<p>pct`` — 1.0 iff the destination buffer is
  bit-identical to the lossless oracle AND the dedup ledger drained to
  zero AND no sender exhausted its retries

Every row is deterministic: the fault process is seeded, so reruns
produce byte-identical numbers.  ``benchmarks/run.py --faults`` gates
the 1%-drop row against the ``[faults]`` section of
``comm_budgets.toml``.

CSV: ``name,value,derived``.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.faults import FaultModel
from repro.core.state import ShoalContext, ERR_RETRY_EXHAUSTED
from repro.runtime import TCP, LossyTransport
from repro.runtime.topology import make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]
PAY_WORDS = 16                          # 4 segments of 4 payload words
MTU_BYTES = 16                          # 4 payload words per packet
DROPS = (0.0, 0.01, 0.05)
SEED = 7


def build(transport):
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(PAY_WORDS, dtype=jnp.float32) + 1) * (me + 1)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1, timeout=True)

    return jax.jit(gas.spmd(prog)), gas


def main():
    tcp = TCP.__class__(name="tcp", acked=True, max_packet_bytes=MTU_BYTES)
    fn0, gas0 = build(tcp)
    oracle = np.asarray(fn0(gas0.make_global_state()).segment)

    print("name,value,derived")
    for drop in DROPS:
        # the 0% row still runs the RELIABLE path (epsilon drop that can
        # never fire) so its tx accounting — headers + acks — is
        # comparable to the lossy rows, not the lossless fast path's
        transport = LossyTransport(
            faults=FaultModel(drop=drop or 1e-12, seed=SEED),
            max_packet_bytes=MTU_BYTES)
        fn, gas = build(transport)
        st = fn(gas.make_global_state())
        seg = np.asarray(st.segment)
        tx = float(np.asarray(st.tx_words).sum())
        delivered = float(N * PAY_WORDS)
        goodput = delivered / tx if tx else 0.0
        rounds = float(np.asarray(st.retransmits).mean())
        exhausted = bool(
            (np.asarray(st.error) & ERR_RETRY_EXHAUSTED).any())
        ok = (np.array_equal(seg, oracle)
              and (np.asarray(st.dedup_seen) == 0).all()
              and not exhausted)
        pct = f"{drop * 100:g}pct"
        print(f"faults/goodput/{pct},{goodput:.4f},tx_words={tx:.0f}")
        print(f"faults/retransmit-rounds/{pct},{rounds:.4f},"
              f"mean of per-kernel retransmits")
        print(f"faults/delivered-ok/{pct},{1.0 if ok else 0.0},"
              f"bit-identical+ledger-drained+no-exhaustion")


if __name__ == "__main__":
    main()
