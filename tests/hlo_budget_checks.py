"""Collective-budget regression checks for the fused wire format.

Run by tests/test_collective_budget.py in a subprocess with 8 host
devices.  Compiles (never executes) the hot AM programs and diffs their
collective counts against the checked-in ``comm_budgets.toml`` through
:mod:`repro.analysis.hlo_budget` — the same pass-2 analyzer CI's
``scripts/comm_lint.py`` runs, so a budget means one thing everywhere.
The wire cost is a *measured* property of the compiled program, not a
belief:

* acked >MTU ``put_long`` (nseg = 4): 2 collective-permutes (one
  batched packet stack + one coalesced reply, down from 3 * nseg = 12
  in the header/payload/reply-per-segment model);
* async >MTU ``put_long``: 1;
* >MTU ``get_medium``: 2 (batched request stack + batched response);
* ``put_long_vectored``: 2 (addresses ride inside the fused packet);
* one full Jacobi iteration with both halo rows segmenting: 4 puts'
  worth of traffic in 2 * 2 collectives;
* ``put_long_multi`` over two disjoint rings: the stacks merge into ONE
  union-permutation collective + one counted group reply (and with
  ``defer_ack=True`` the reply disappears entirely: 1 collective);
* sub-32-bit (bf16) acked put: the split header/payload fallback is 3
  collectives — budgeted so the fallback's cost stays measured, and its
  ``tx_words`` accounting (bytes on wire, not element count) is covered
  by tests/md_checks.py;
* a two-pattern ``MultiMailbox`` flush: both sub-stacks cross as one
  grouped collective + one counted reply.
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis import hlo_budget
from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime import TCP, UDP
from repro.runtime.topology import make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]
TINY_TCP = dataclasses.replace(TCP, max_packet_bytes=64)   # 16 words
TINY_UDP = dataclasses.replace(UDP, max_packet_bytes=64)
NSEG = 4                                                   # 50 words / 16

BUDGETS = hlo_budget.load_budgets()


def measure(gas, prog, *extra):
    state0 = gas.make_global_state()
    hlo = jax.jit(gas.spmd(prog)).lower(state0, *extra).compile().as_text()
    return hlo_budget.measure(hlo)


def check(section, stats):
    spec = BUDGETS.get(section)
    assert spec, f"comm_budgets.toml is missing a [{section}] section"
    findings = hlo_budget.check_budget(section, stats, spec)
    assert not findings, "\n".join(f.render() for f in findings)
    cps = stats.ops.get("collective-permute", 0.0)
    print(f"[hlo-budget] {section}: {cps:.0f} collective-permutes ok")


def main():
    mesh = make_cpu_mesh(N, ("kernel",))

    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TINY_TCP,
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)

    def put_acked(st):
        pay = jnp.arange(50, dtype=jnp.float32)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=8, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    check("micro.put_long_acked_4seg", measure(gas, put_acked))

    def get4(st):
        st, data = ops.get_medium(ctx, st, RING, src_addr=0, nwords=50,
                                  token=2)
        return ops.wait_replies(ctx, st, token=2, n=1)

    check("micro.get_medium_4seg", measure(gas, get4))

    def vectored(st):
        return ops.put_long_vectored(
            ctx, st, [jnp.ones(2, jnp.float32), jnp.ones(3, jnp.float32)],
            RING, dst_addrs=[40, 60], token=3)

    check("micro.put_long_vectored", measure(gas, vectored))

    ctx_u = ShoalContext(mesh=mesh, axes=("kernel",), transport=TINY_UDP,
                         segment_words=128)
    gas_u = GlobalAddressSpace(ctx_u)

    def put_async(st):
        pay = jnp.arange(50, dtype=jnp.float32)
        return ops.put_long(ctx_u, st, pay, RING, dst_addr=8, token=1,
                            asynchronous=True)

    check("micro.put_long_async_4seg", measure(gas_u, put_async))

    # two disjoint rings (even->odd, odd->even): sources AND dests are
    # disjoint, so both packet stacks merge into one union ppermute;
    # the whole group acks through ONE counted reply
    EVEN = [(i, i + 1) for i in range(0, N, 2)]
    ODD = [(i, (i + 1) % N) for i in range(1, N, 2)]

    def multi_merged(st):
        items = [(jnp.arange(50, dtype=jnp.float32), EVEN, 8),
                 (jnp.ones((34,), jnp.float32), ODD, 64)]
        st = ops.put_long_multi(ctx, st, items, token=4)
        return ops.wait_replies(ctx, st, token=4, n=1)

    check("micro.put_long_multi_merged", measure(gas, multi_merged))

    def multi_deferred(st):
        items = [(jnp.arange(50, dtype=jnp.float32), EVEN, 8),
                 (jnp.ones((34,), jnp.float32), ODD, 64)]
        st = ops.put_long_multi(ctx, st, items, token=4, defer_ack=True)
        # receivers ledger the acks; a later reverse-link packet (or a
        # drain) carries them home — nothing more to ship HERE
        return st

    check("micro.put_long_multi_deferred", measure(gas, multi_deferred))

    # sub-32-bit payloads can't bitcast onto the int32 wire: the acked
    # put falls back to split header + payload collectives + 1 reply
    gas_b = GlobalAddressSpace(ctx, dtype=jnp.bfloat16)

    def put_bf16(st):
        pay = jnp.ones((10,), jnp.bfloat16)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=8, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    check("micro.put_long_bf16_fallback", measure(gas_b, put_bf16))

    # MultiMailbox over the two disjoint rings: 3 sends per pattern
    # flush as ONE grouped stack + ONE counted reply (2 credits)
    from repro.actors import MultiMailbox

    def multi_flush(st):
        mmb = MultiMailbox(ctx, [EVEN, ODD], msg_words=4,
                           watermark=1 << 20, token=6)
        base = jnp.arange(4, dtype=jnp.float32)
        for i in range(6):
            st = mmb.send(st, i % 2, base + i, dst_addr=4 * i)
        st = mmb.flush(st)
        return ops.wait_replies(ctx, st, token=6, n=1)

    check("micro.multi_mailbox_flush", measure(gas, multi_flush))

    # one full Jacobi iteration with segmenting halo rows: n=64 grid on
    # 8 kernels, 16-word MTU -> each 64-word halo row is 4 packets; two
    # halo messages/iteration -> 2 * (1 packet stack + 1 reply) = 4.
    from repro.apps.jacobi import JacobiApp
    app = JacobiApp(n=64, kernels=N, iters=1, transport=TINY_TCP)
    fn = app.build()
    gas_j = GlobalAddressSpace(app.ctx)
    st = gas_j.make_global_state()
    blocks = jnp.zeros((N, 64 // N, 64), jnp.float32)
    hlo = fn.lower(st, blocks).compile().as_text()
    check("micro.jacobi_iter_segmenting", hlo_budget.measure(hlo))

    print("HLO_BUDGET_OK")


if __name__ == "__main__":
    main()
