"""Multi-device semantic checks for the Shoal library, the trainer
backends, and elastic restart.  Run by tests/test_multidevice.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime.jax_compat import make_mesh as compat_make_mesh, shard_map

from repro.core import collectives as coll
from repro.core import handlers as hd
from repro.core import humboldt, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime import TCP, UDP, make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]


def check(name):
    print(f"[md] {name}", flush=True)


def test_put_long_ring():
    check("put_long ring + wait_replies + barrier")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(4, dtype=jnp.float32) + 1) * (me + 1).astype(jnp.float32)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        st = ops.barrier(ctx, st)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src = (k - 1) % N
        np.testing.assert_allclose(seg[k, 10:14], (np.arange(4) + 1) * (src + 1))
    assert (np.asarray(st.error) == 0).all()
    assert (np.asarray(st.barrier_epoch) == 1).all()
    assert (np.asarray(st.credits) == 0).all()     # drained


def test_accumulate_and_get():
    check("put_long H_ADD + get_medium + get_long")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        st = ops.put_long(ctx, st, jnp.ones(2, jnp.float32) * (me + 1).astype(jnp.float32),
                          RING, dst_addr=0, handler=hd.H_ADD, token=1)
        st = ops.put_long(ctx, st, jnp.ones(2, jnp.float32), RING, dst_addr=0,
                          handler=hd.H_ADD, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=2)
        # fetch my successor's segment[0:2]
        st, data = ops.get_medium(ctx, st, RING, src_addr=0, nwords=2, token=2)
        st = ops.wait_replies(ctx, st, token=2, n=1)
        seg = jax.lax.dynamic_update_slice(st.segment, data, (30,))
        from repro.core.gascore import dataclasses_replace
        st = dataclasses_replace(st, segment=seg)
        # one-sided read into local segment at 40
        st = ops.get_long(ctx, st, RING, src_addr=0, nwords=2, dst_addr=40,
                          token=3)
        st = ops.wait_replies(ctx, st, token=3, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src = (k - 1) % N
        expect = src + 2.0
        np.testing.assert_allclose(seg[k, 0:2], expect)      # accumulated
        succ = (k + 1) % N
        np.testing.assert_allclose(seg[k, 30:32], k + 2.0)   # what succ holds
        np.testing.assert_allclose(seg[k, 40:42], k + 2.0)
    assert (np.asarray(st.error) == 0).all()


def test_strided_vectored():
    check("put_long_strided + put_long_vectored")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        pay = jnp.arange(6, dtype=jnp.float32) + 10 * me1
        st = ops.put_long_strided(ctx, st, pay, RING, dst_addr=4, stride=10,
                                  blk_words=2, nblocks=3, token=1)
        st = ops.put_long_vectored(ctx, st,
                                   [jnp.full(2, me1), jnp.full(3, -me1)],
                                   RING, dst_addrs=[50, 60], token=2)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        st = ops.wait_replies(ctx, st, token=2, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src1 = ((k - 1) % N) + 1
        base = np.arange(6) + 10 * src1
        np.testing.assert_allclose(seg[k, 4:6], base[0:2])
        np.testing.assert_allclose(seg[k, 14:16], base[2:4])
        np.testing.assert_allclose(seg[k, 24:26], base[4:6])
        np.testing.assert_allclose(seg[k, 50:52], src1)
        np.testing.assert_allclose(seg[k, 60:63], -src1)
    assert (np.asarray(st.error) == 0).all()


def test_mtu_segmentation():
    check(">MTU segmentation (the paper's jumbo-frame limit, implemented)")
    mesh = make_cpu_mesh(N, ("kernel",))
    import dataclasses
    tiny_tcp = dataclasses.replace(TCP, max_packet_bytes=64)   # 16 words
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=tiny_tcp,
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        pay = jnp.arange(50, dtype=jnp.float32) + 100 * me1
        st = ops.put_long(ctx, st, pay, RING, dst_addr=8, token=1)
        # 50 words / 16-word packets -> 4 packets, ONE coalesced reply:
        # only the final segment of a message is acked
        st = ops.wait_replies(ctx, st, token=1, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src1 = ((k - 1) % N) + 1
        np.testing.assert_allclose(seg[k, 8:58], np.arange(50) + 100 * src1)
    assert (np.asarray(st.error) == 0).all(), \
        "expected one coalesced reply per message"


def test_mtu_segmentation_edge():
    check(">MTU put flush against the segment end (partial final packet)")
    mesh = make_cpu_mesh(N, ("kernel",))
    import dataclasses
    tiny_tcp = dataclasses.replace(TCP, max_packet_bytes=64)   # 16 words
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=tiny_tcp,
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        pay = jnp.arange(50, dtype=jnp.float32) + 100 * me1
        # 78 + 50 = 128: the partial 2-word final packet lands flush
        # against the segment end
        st = ops.put_long(ctx, st, pay, RING, dst_addr=78, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src1 = ((k - 1) % N) + 1
        np.testing.assert_allclose(seg[k, 78:128], np.arange(50) + 100 * src1)
    assert (np.asarray(st.error) == 0).all()


def test_mtu_gets_and_strided():
    check(">MTU get_medium / get_long / put_long_strided (batched plans)")
    mesh = make_cpu_mesh(N, ("kernel",))
    import dataclasses
    tiny_tcp = dataclasses.replace(TCP, max_packet_bytes=64)   # 16 words
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=tiny_tcp,
                       segment_words=256)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        from repro.core.gascore import dataclasses_replace
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        # seed my own segment [0, 50) with a recognizable ramp
        ramp = jnp.arange(50, dtype=jnp.float32) + 100 * me1
        st = dataclasses_replace(
            st, segment=jax.lax.dynamic_update_slice(st.segment, ramp, (0,)))
        # 50-word get_medium: 4 request packets, one batched response,
        # ONE credit for the whole message
        st, data = ops.get_medium(ctx, st, RING, src_addr=0, nwords=50,
                                  token=2)
        st = ops.wait_replies(ctx, st, token=2, n=1)
        st = dataclasses_replace(
            st, segment=jax.lax.dynamic_update_slice(st.segment, data, (60,)))
        # 50-word get_long into my segment at 120
        st = ops.get_long(ctx, st, RING, src_addr=0, nwords=50, dst_addr=120,
                          token=3)
        st = ops.wait_replies(ctx, st, token=3, n=1)
        # strided put: 10 blocks of 3 words, stride 5 -> lands at
        # 180 + i*5; 30 words > 16-word MTU so it segments at block
        # granularity (5 blocks per packet, 2 packets, one reply)
        pay = jnp.arange(30, dtype=jnp.float32) + 1000 * me1
        st = ops.put_long_strided(ctx, st, pay, RING, dst_addr=180, stride=5,
                                  blk_words=3, nblocks=10, token=4)
        st = ops.wait_replies(ctx, st, token=4, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        succ1 = ((k + 1) % N) + 1      # gets fetch from my successor
        pred1 = ((k - 1) % N) + 1      # strided put arrives from predecessor
        np.testing.assert_allclose(seg[k, 60:110],
                                   np.arange(50) + 100 * succ1)
        np.testing.assert_allclose(seg[k, 120:170],
                                   np.arange(50) + 100 * succ1)
        for i in range(10):
            np.testing.assert_allclose(
                seg[k, 180 + 5 * i:183 + 5 * i],
                np.arange(3) + 3 * i + 1000 * pred1)
    assert (np.asarray(st.error) == 0).all()


def test_async_udp_semantics():
    check("async (UDP) suppresses replies; wait flags underflow")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=UDP,
                       segment_words=32)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        st = ops.put_long(ctx, st, jnp.ones(2, jnp.float32), RING,
                          dst_addr=0, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    assert (np.asarray(st.error) == 1).all()
    np.testing.assert_allclose(np.asarray(st.segment)[:, 0:2], 1.0)


def test_put_long_multi_semantics():
    check("put_long_multi: disjoint rings merge, interleaved stacks land")
    import dataclasses
    mesh = make_cpu_mesh(N, ("kernel",))
    tiny = dataclasses.replace(TCP, max_packet_bytes=64)   # 16-word MTU
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=tiny,
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)
    even = [(i, i + 1) for i in range(0, N, 2)]        # srcs/dsts disjoint
    odd = [(i, (i + 1) % N) for i in range(1, N, 2)]   # from even's: merge

    def prog(st):
        me = ctx.my_id().astype(jnp.float32)
        # 40 words = 3 rows at the 16-word MTU, 10 words = 1 row; the
        # two stacks interleave in one union-permutation collective
        items = [(jnp.arange(40, dtype=jnp.float32) + 1000.0 * me, even, 8),
                 (jnp.arange(10, dtype=jnp.float32) - 1000.0 * me, odd, 64)]
        st = ops.put_long_multi(ctx, st, items, token=4)
        return ops.wait_replies(ctx, st, token=4, n=1)

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        src = (k - 1) % N
        if k % 2 == 1:     # receives the even-ring item
            np.testing.assert_allclose(seg[k, 8:48],
                                       np.arange(40.0) + 1000.0 * src)
        else:              # receives the odd-ring item
            np.testing.assert_allclose(seg[k, 64:74],
                                       np.arange(10.0) - 1000.0 * src)
    # every kernel sent exactly one item and the ONE counted group reply
    # returned exactly one credit for it, drained by the wait
    assert (np.asarray(st.credits) == 0).all()
    assert (np.asarray(st.error) == 0).all()


def test_put_long_multi_alias_guard():
    check("put_long_multi: cross-item overlap raises VectoredAliasError")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=64)
    gas = GlobalAddressSpace(ctx)
    # both items land on kernel 1; [8, 12) and [10, 14) overlap, so the
    # value at [10, 12) depends on stack order
    items_of = lambda: [(jnp.ones(4, jnp.float32), [(0, 1)], 8),
                        (jnp.full((4,), 2.0), [(2, 1)], 10)]

    def prog(st):
        return ops.put_long_multi(ctx, st, items_of(), token=1,
                                  asynchronous=True)

    try:
        jax.jit(gas.spmd(prog)).lower(gas.make_global_state())
        raised = False
    except ops.VectoredAliasError:
        raised = True
    assert raised, "overlapping put_long_multi items must raise"

    from repro.analysis import waiver

    def prog_waived(st):
        with waiver("alias test: last-writer-wins is intended"):
            return ops.put_long_multi(ctx, st, items_of(), token=1,
                                      asynchronous=True)

    jax.jit(gas.spmd(prog_waived)).lower(gas.make_global_state())


def test_piggyback_steady_loop():
    check("reply piggybacking: 2 CPs/iteration steady state, clean drain")
    from repro.analysis import hlo_budget
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=64)
    gas = GlobalAddressSpace(ctx)
    rring = [((i + 1) % N, i) for i in range(N)]
    iters = 5

    def prog(st):
        def body(st, it):
            # forward puts defer acks (token 1); the reverse packet
            # piggybacks them home, and vice versa (token 2) — zero ack
            # collectives inside the loop
            items = [(jnp.full((4,), 1.0 + it), RING, 8),
                     (jnp.full((4,), 101.0 + it), rring, 16)]
            st = ops.put_long_multi(ctx, st, items, tokens=[1, 2],
                                    defer_ack=True, piggyback_tokens=[2, 1])
            # iteration k's acks ride iteration k+1's packets
            ready = (it > 0).astype(jnp.int32)
            st = ops.wait_replies(ctx, st, token=1, n=ready)
            st = ops.wait_replies(ctx, st, token=2, n=ready)
            return st, ()

        st, _ = jax.lax.scan(body, st, jnp.arange(iters))
        # loop exit: the final iteration's acks are still ledgered at
        # the receivers; one drain per link ships them home
        st = ops.drain_deferred_acks(ctx, st, rring, token=1)
        st = ops.drain_deferred_acks(ctx, st, RING, token=2)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        st = ops.wait_replies(ctx, st, token=2, n=1)
        return st

    jitted = jax.jit(gas.spmd(prog))
    st0 = gas.make_global_state()
    st = jitted(st0)
    seg = np.asarray(st.segment)
    for k in range(N):
        np.testing.assert_allclose(seg[k, 8:12], float(iters))       # 1+it
        np.testing.assert_allclose(seg[k, 16:20], 100.0 + iters)
    # no ack stranded: every deferred ack was piggybacked or drained,
    # every credit consumed, no underflow tripped
    assert (np.asarray(st.deferred_acks) == 0).all()
    assert (np.asarray(st.credits) == 0).all()
    assert (np.asarray(st.error) == 0).all()
    # the whole program is 2 CPs per iteration (trip-weighted) + the 2
    # one-off drains — the per-iteration ack collectives are GONE
    stats = hlo_budget.measure(jitted.lower(st0).compile().as_text())
    cps = stats.ops.get("collective-permute", 0.0)
    assert cps == 2 * iters + 2, f"steady state regressed: {cps} CPs"


def test_bf16_wire_accounting():
    check("sub-32-bit (bf16) split fallback: bytes-on-wire tx accounting")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=64)
    gas = GlobalAddressSpace(ctx, dtype=jnp.bfloat16)

    def prog(st):
        me1 = (ctx.my_id() + 1).astype(jnp.bfloat16)
        pay = jnp.full((10,), 1.0, jnp.bfloat16) * me1
        st = ops.put_long(ctx, st, pay, RING, dst_addr=4, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment.astype(jnp.float32))
    for k in range(N):
        np.testing.assert_allclose(seg[k, 4:14], ((k - 1) % N) + 1.0)
    # 10 bf16 words are 20 bytes = 5 int32 wire words, not 10: the old
    # element-count accounting overstated sub-32-bit wire volume 2x
    assert (np.asarray(st.tx_words) == 5).all(), np.asarray(st.tx_words)
    assert (np.asarray(st.error) == 0).all()


def test_humboldt_two_sided():
    check("HUMboldt 4-phase send/recv")
    mesh = make_cpu_mesh(N, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                       segment_words=32)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        st, recv = humboldt.sendrecv(ctx, st, me1 * jnp.ones(3), RING, token=4)
        from repro.core.gascore import dataclasses_replace
        st = dataclasses_replace(
            st, segment=jax.lax.dynamic_update_slice(st.segment, recv, (4,)))
        st = ops.wait_replies(ctx, st, token=4, n=1)
        return st

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)
    for k in range(N):
        np.testing.assert_allclose(seg[k, 4:7], ((k - 1) % N) + 1)
    assert (np.asarray(st.error) == 0).all()


def test_ring_collectives():
    check("ring collectives vs lax references")
    mesh = make_cpu_mesh(N, ("kernel",))
    xs = jnp.asarray(np.random.default_rng(0).standard_normal((N, 37)),
                     jnp.float32)

    def ar(x):
        return coll.ring_all_reduce(x, ("kernel",), N)

    out = jax.jit(shard_map(ar, mesh=mesh, in_specs=P("kernel"),
                                out_specs=P("kernel")))(xs)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(xs).sum(0), (N, 1)),
                               rtol=1e-5)

    def rs(x):
        return coll.ring_reduce_scatter(x, ("kernel",), N)

    xs2 = jnp.asarray(np.random.default_rng(1).standard_normal((N, 40)),
                      jnp.float32)
    out = jax.jit(shard_map(rs, mesh=mesh, in_specs=P("kernel"),
                                out_specs=P("kernel")))(xs2)
    np.testing.assert_allclose(np.asarray(out).reshape(N, 5),
                               np.asarray(xs2).sum(0).reshape(N, 5), rtol=1e-5)

    def bc(x):
        return coll.broadcast_from(x, ("kernel",), N, root=5)

    out = jax.jit(shard_map(bc, mesh=mesh, in_specs=P("kernel"),
                                out_specs=P("kernel")))(xs)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(xs)[5], (N, 1)))


def test_trainer_backends_agree():
    check("xla vs shoal trainer backends + int8 EF + quorum")
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adamw import AdamWConfig
    from repro.training.train import Trainer, TrainerConfig
    from repro.data.pipeline import DataConfig, TokenPipeline

    mesh = make_cpu_mesh(N, ("kernel",))
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      dtype=jnp.float32)
    batch, _ = TokenPipeline(DataConfig(vocab=256, batch=8, seq=32,
                                        seed=1)).next_batch(0)
    b = {k: jax.device_put(v, NamedSharding(mesh, P(("data",))))
         for k, v in batch.items()}

    m1 = build_model(cfg, mesh=mesh, dp_axes=("data",))
    tr1 = Trainer(m1, AdamWConfig(lr=1e-3), TrainerConfig(donate=False))
    st1 = tr1.init_state(jax.random.PRNGKey(0))
    s1, met1 = tr1.make_train_step()(st1, b)

    m2 = build_model(cfg, mesh=mesh, dp_axes=())
    tr2 = Trainer(m2, AdamWConfig(lr=1e-3),
                  TrainerConfig(comm_backend="shoal", donate=False),
                  dp_axes=("data",))
    st2 = tr2.init_state(jax.random.PRNGKey(0))
    s2, met2 = tr2.make_train_step()(st2, b)
    assert abs(float(met1["loss"]) - float(met2["loss"])) < 1e-4
    deltas = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(a - c))),
                          s1.params, s2.params)
    assert max(jax.tree.leaves(deltas)) < 1e-4

    tr3 = Trainer(m2, AdamWConfig(lr=1e-3),
                  TrainerConfig(comm_backend="shoal", grad_compression=True,
                                donate=False), dp_axes=("data",))
    st3 = tr3.init_state(jax.random.PRNGKey(0))
    s3, met3 = tr3.make_train_step()(st3, b)
    deltas3 = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(a - c))),
                           s1.params, s3.params)
    assert max(jax.tree.leaves(deltas3)) < 5e-2   # int8 quantization error

    # quorum DP: dropping one rank = mean over survivors
    from repro.training.elastic import quorum_mean_grads
    def qfn(g, live):
        out, n_live = quorum_mean_grads({"g": g}, live, ("data",))
        return out["g"], n_live
    g = jnp.asarray(np.arange(2 * 3, dtype=np.float32).reshape(2, 3))
    live = jnp.asarray([1.0, 0.0])
    out, n_live = jax.jit(shard_map(
        qfn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data", None)) if False else (P("data"), P("data"))))(g, live)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(g)[0])
    assert float(np.asarray(n_live)[0]) == 1.0


def test_elastic_reshard():
    check("checkpoint save on 8-way mesh, restore on 4-way mesh")
    from repro.checkpoint import CheckpointManager
    mesh8 = compat_make_mesh((8,), ("data",))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(7, {"w": xs}, extras={"data_step": 123})
        devs = jax.devices()[:4]
        mesh4 = jax.sharding.Mesh(np.asarray(devs).reshape(4), ("data",))
        tree, extras = mgr.restore(
            {"w": x}, shardings={"w": NamedSharding(mesh4, P("data", None))},
            verify=True)
        assert extras["data_step"] == 123
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
        assert len(tree["w"].sharding.device_set) == 4


def test_ring_attention_exact():
    check("ring attention (seq-parallel, one-sided-put KV rotation)")
    from repro.models.ring_attention import ring_attention
    from repro.models.attention import _attend
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    B, S, K, G, dh = 2, 64, 2, 3, 16
    q = jnp.asarray(rng.standard_normal((B, S, K, G, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    out = jax.jit(lambda *a: ring_attention(mesh, "model", ("data",), *a))(
        q, k, v, pos)
    want = _attend(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_seq_shard_model_exact():
    check("seq_shard (ring) model forward+grad vs baseline")
    import dataclasses
    from repro.models.model import ModelConfig, build_model
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype=jnp.float32, tp=False, seq_shard=True)
    m1 = build_model(cfg, mesh=mesh, dp_axes=("data",))
    m2 = build_model(dataclasses.replace(cfg, seq_shard=False))
    params = m2.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 32)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    l1 = jax.jit(m1.loss)(params, batch)
    l2 = jax.jit(m2.loss)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
    g1 = jax.jit(jax.grad(m1.loss))(params, batch)
    g2 = jax.jit(jax.grad(m2.loss))(params, batch)
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)))
    assert d < 1e-4, d


def test_moe_dispatch_variants_exact():
    check("EP island dispatch variants (psum/rs/a2a) vs oracle")
    import dataclasses
    from repro.models.model import ModelConfig, build_model
    from repro.models.moe import MoEDims
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    base = MoEDims(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                   capacity_factor=16.0)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (4, 32)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    cfg0 = ModelConfig(name="tm", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
                       fsdp=True, aux_loss_weight=0.0, moe=base,
                       dtype=jnp.float32)
    oracle = build_model(dataclasses.replace(cfg0, fsdp=False))
    params = oracle.init(jax.random.PRNGKey(1))
    l_ref = float(jax.jit(oracle.loss)(params, batch))
    for dispatch, seq in (("psum", False), ("rs", True), ("a2a", True)):
        cfg = dataclasses.replace(
            cfg0, seq_shard=seq,
            moe=dataclasses.replace(base, dispatch=dispatch))
        m = build_model(cfg, mesh=mesh, dp_axes=("data",))
        l = float(jax.jit(m.loss)(params, batch))
        assert abs(l - l_ref) < 5e-5, (dispatch, l, l_ref)


def test_gascore_rdma_ring():
    check("Pallas RDMA ring all-reduce (the literal GAScore) vs psum")
    from repro.kernels.gascore_dma import ring_allreduce_dma
    mesh = compat_make_mesh((8,), ("x",))
    for chunk, dt, tol in [(128, jnp.float32, 1e-5), (64, jnp.bfloat16, 5e-2)]:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(8 * chunk),
                        dt)
        got = np.asarray(ring_allreduce_dma(mesh, "x", x),
                         np.float32).reshape(8, chunk)
        want = np.asarray(x, np.float32).reshape(8, chunk).sum(0)
        for r in range(8):
            np.testing.assert_allclose(got[r], want, rtol=tol, atol=tol)


def test_pipeline_parallel():
    check("2-stage pipeline over the pod axis (Medium-AM handoffs)")
    from repro.training.pipeline import pipeline_apply, split_stages
    mesh = compat_make_mesh((2, 4), ("pod", "chip"))
    rng = np.random.default_rng(0)
    L, d = 4, 16
    w = jnp.asarray(rng.standard_normal((L, d, d)) * 0.3, jnp.float32)

    def stage_fn(pslice, x):          # pslice: (L/2, d, d)
        def body(x, wl):
            return jnp.tanh(x @ wl), ()
        x, _ = jax.lax.scan(body, x, pslice["w"])
        return x

    M, mb = 3, 5
    xs = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)
    out = jax.jit(lambda p, x: pipeline_apply(
        mesh, "pod", stage_fn, p, x))(split_stages({"w": w}, 2), xs)

    # sequential reference
    ref = xs
    for l in range(L):
        ref = jnp.tanh(ref @ w[l])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def main():
    test_put_long_ring()
    test_accumulate_and_get()
    test_strided_vectored()
    test_mtu_segmentation()
    test_mtu_segmentation_edge()
    test_mtu_gets_and_strided()
    test_async_udp_semantics()
    test_put_long_multi_semantics()
    test_put_long_multi_alias_guard()
    test_piggyback_steady_loop()
    test_bf16_wire_accounting()
    test_humboldt_two_sided()
    test_ring_collectives()
    test_trainer_backends_agree()
    test_elastic_reshard()
    test_ring_attention_exact()
    test_seq_shard_model_exact()
    test_moe_dispatch_variants_exact()
    test_gascore_rdma_ring()
    test_pipeline_parallel()
    print("MD_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
