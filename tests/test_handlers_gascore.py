"""Handler table + GAScore datapath unit tests (single device; the
GAScore stages are pure functions over headers/payloads/state)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import am, gascore as gc, handlers as hd
from repro.core.state import PgasState, ShoalContext
from repro.runtime.topology import make_cpu_mesh


def make_ctx(segment_words=64):
    mesh = make_cpu_mesh(1, ("kernel",))
    return ShoalContext(mesh=mesh, axes=("kernel",),
                        segment_words=segment_words)


def test_builtin_handlers():
    t = hd.HandlerTable()
    r = jnp.asarray([1.0, 2.0])
    p = jnp.asarray([10.0, 20.0])
    np.testing.assert_allclose(t.dispatch(hd.H_NOP, r, p), [1, 2])
    np.testing.assert_allclose(t.dispatch(hd.H_WRITE, r, p), [10, 20])
    np.testing.assert_allclose(t.dispatch(hd.H_ADD, r, p), [11, 22])
    np.testing.assert_allclose(t.dispatch(hd.H_MAX, r, p), [10, 20])
    np.testing.assert_allclose(t.dispatch(hd.H_MIN, r, p), [1, 2])


def test_custom_handler_registration():
    t = hd.HandlerTable()
    hid = t.register("scale2", lambda r, p: r + 2 * p)
    assert hid == hd.NUM_BUILTIN
    out = t.dispatch(hid, jnp.asarray([1.0]), jnp.asarray([3.0]))
    np.testing.assert_allclose(out, [7.0])


@settings(max_examples=25, deadline=None)
@given(handler=st.integers(0, hd.NUM_BUILTIN - 1))
def test_dispatch_traced_id(handler):
    t = hd.HandlerTable()
    r = jnp.asarray([2.0])
    p = jnp.asarray([5.0])
    expected = [r[0], p[0], r[0] + p[0], jnp.maximum(r, p)[0],
                jnp.minimum(r, p)[0]][handler]
    out = t.dispatch(jnp.asarray(handler), r, p)
    np.testing.assert_allclose(out[0], expected)


def test_ingress_long_write_and_masking():
    ctx = make_ctx()
    st_ = PgasState.make(64)
    pay = jnp.arange(1.0, 5.0)
    hdr = am.decode(am.encode(type=am.make_type(am.LONG), nwords=4,
                              dst_addr=10, handler=hd.H_WRITE))
    out = gc.ingress_long(ctx, st_, hdr, pay, 4)
    np.testing.assert_allclose(out.segment[10:14], [1, 2, 3, 4])
    assert int(out.rx_words) == 4
    # NOP header leaves the segment bit-identical
    nop = am.decode(jnp.zeros((am.HDR_WORDS,), jnp.int32))
    out2 = gc.ingress_long(ctx, out, nop, pay, 4)
    np.testing.assert_array_equal(out2.segment, out.segment)
    assert int(out2.rx_words) == 4


def test_ingress_long_partial_lanes():
    """nwords < packet width: only valid lanes land."""
    ctx = make_ctx()
    st_ = PgasState.make(64)
    pay = jnp.arange(1.0, 9.0)
    hdr = am.decode(am.encode(type=am.make_type(am.LONG), nwords=3,
                              dst_addr=0, handler=hd.H_WRITE))
    out = gc.ingress_long(ctx, st_, hdr, pay, 8)
    np.testing.assert_allclose(out.segment[:8], [1, 2, 3, 0, 0, 0, 0, 0])


def test_ingress_long_accumulate():
    ctx = make_ctx()
    st_ = PgasState.make(64)
    st_ = gc.dataclasses_replace(st_, segment=st_.segment.at[5].set(10.0))
    hdr = am.decode(am.encode(type=am.make_type(am.LONG), nwords=1,
                              dst_addr=5, handler=hd.H_ADD))
    out = gc.ingress_long(ctx, st_, hdr, jnp.asarray([7.0]), 1)
    assert float(out.segment[5]) == 17.0


def test_serve_get_and_suppression():
    ctx = make_ctx()
    st_ = PgasState.make(64)
    st_ = gc.dataclasses_replace(
        st_, segment=st_.segment.at[20:24].set(jnp.arange(4.0)))
    hdr = am.decode(am.encode(type=am.make_type(am.MEDIUM, get=True),
                              nwords=4, src_addr=20, token=2))
    st2, resp_hdr, data = gc.serve_get(ctx, st_, hdr, 4)
    np.testing.assert_allclose(data, [0, 1, 2, 3])
    rh = am.decode(resp_hdr)
    assert bool(rh.flag(am.FLAG_REPLY))
    # non-get header produces a NOP response (no spurious credits)
    nop_hdr = am.decode(am.encode(type=am.make_type(am.MEDIUM), nwords=4))
    _, resp2, data2 = gc.serve_get(ctx, st_, nop_hdr, 4)
    assert int(am.decode(resp2).msg_class) == am.NOP
    np.testing.assert_allclose(data2, 0)


def test_reply_credits():
    st_ = PgasState.make(8)
    rep = am.decode(am.reply_for(am.decode(
        am.encode(type=am.make_type(am.LONG), src=0, dst=1, token=3))))
    out = gc.ingress_reply(st_, rep)
    assert int(out.credits[3]) == 1
    # non-replies do not bump credits
    out2 = gc.ingress_reply(out, am.decode(
        am.encode(type=am.make_type(am.SHORT), token=3)))
    assert int(out2.credits[3]) == 1


def test_ingress_short_semaphore():
    ctx = make_ctx()
    st_ = PgasState.make(8)
    hdr = am.decode(am.encode(type=am.make_type(am.SHORT), handler=hd.H_ADD,
                              token=2, dst_addr=5))
    out = gc.ingress_short(ctx, st_, hdr)
    assert int(out.credits[2]) == 5


def test_auto_reply_suppression():
    acked = am.decode(am.encode(type=am.make_type(am.LONG), src=1, dst=2))
    asyn = am.decode(am.encode(
        type=am.make_type(am.LONG, asynchronous=True), src=1, dst=2))
    assert int(am.decode(gc.auto_reply(acked)).msg_class) == am.SHORT
    assert int(am.decode(gc.auto_reply(asyn)).msg_class) == am.NOP
    nop = am.decode(jnp.zeros((am.HDR_WORDS,), jnp.int32))
    assert int(am.decode(gc.auto_reply(nop)).msg_class) == am.NOP


def test_egress_memory_sourced():
    ctx = make_ctx()
    st_ = PgasState.make(64)
    st_ = gc.dataclasses_replace(
        st_, segment=st_.segment.at[8:12].set(jnp.arange(4.0) + 1))
    hdr = am.decode(am.encode(type=am.make_type(am.LONG), nwords=4,
                              src_addr=8))
    buf = gc.egress(ctx, st_, hdr, None, 4)
    np.testing.assert_allclose(buf, [1, 2, 3, 4])


def test_put_calling_conventions_validated():
    """payload=None with no (from_segment_addr, nwords) is a usage error
    and must raise a ValueError naming both conventions, not crash with
    an opaque AttributeError on payload.reshape."""
    from repro.core import ops
    ctx = make_ctx()
    st_ = PgasState.make(64)
    for op in (lambda: ops.put_medium(ctx, st_, None, [(0, 0)]),
               lambda: ops.put_long(ctx, st_, None, [(0, 0)], dst_addr=0),
               lambda: ops.put_medium(ctx, st_, None, [(0, 0)], nwords=4),
               lambda: ops.put_long(ctx, st_, None, [(0, 0)], dst_addr=0,
                                    nwords=4)):
        with pytest.raises(ValueError, match="FIFO|memory-sourced"):
            op()


def test_egress_batch_matches_single():
    """The batched egress path agrees with per-row egress for both the
    FIFO and the memory-sourced variants."""
    ctx = make_ctx(segment_words=64)
    st_ = PgasState.make(64)
    st_ = gc.dataclasses_replace(
        st_, segment=st_.segment.at[:64].set(jnp.arange(64.0)))
    # memory-sourced rows, incl. a partial final row flush with the end
    rows = am.encode_batch(3, type=am.make_type(am.LONG), nwords=jnp.asarray([8, 8, 4]),
                           src_addr=jnp.asarray([44, 52, 60]))
    out = gc.egress_batch(ctx, st_, rows, None, 8)
    np.testing.assert_allclose(out[0], np.arange(44.0, 52.0))
    np.testing.assert_allclose(out[1], np.arange(52.0, 60.0))
    np.testing.assert_allclose(out[2], [60, 61, 62, 63, 0, 0, 0, 0])
    # FIFO rows: flat payload split row-wise, last row zero-padded
    fifo = gc.egress_batch(ctx, st_, rows, jnp.arange(20.0), 8)
    np.testing.assert_allclose(fifo.reshape(-1)[:20], np.arange(20.0))
    np.testing.assert_allclose(fifo[2][4:], 0.0)


def test_ingress_strided_vectorized_matches_ref():
    """The flat gather/scatter strided ingress lands blocks exactly
    where the am_pack oracle's index map says."""
    from repro.kernels.am_pack import am_unpack_ref
    ctx = make_ctx(segment_words=64)
    st_ = PgasState.make(64)
    pay = jnp.arange(1.0, 7.0)
    hdr = am.decode(am.encode(type=am.make_type(am.LONG, strided=True),
                              nwords=6, dst_addr=5, stride=9, blk_words=2,
                              nblocks=3, handler=hd.H_WRITE))
    out = gc.ingress_strided(ctx, st_, hdr, pay, 2, 3)
    want = am_unpack_ref(st_.segment, pay, 5, 9, 2, 3)
    np.testing.assert_allclose(out.segment, want)
    # dynamic nblocks below the static capacity: trailing blocks dropped
    hdr2 = am.decode(am.encode(type=am.make_type(am.LONG, strided=True),
                               nwords=4, dst_addr=5, stride=9, blk_words=2,
                               nblocks=2, handler=hd.H_WRITE))
    out2 = gc.ingress_strided(ctx, st_, hdr2, pay, 2, 3)
    np.testing.assert_allclose(out2.segment[5:7], [1, 2])
    np.testing.assert_allclose(out2.segment[14:16], [3, 4])
    np.testing.assert_allclose(out2.segment[23:25], 0.0)


def _strided_seq_ref(segment, payload, dst_addr, stride, blk_words, nblocks,
                     handler):
    """Numpy oracle: blocks applied strictly in order, so later blocks
    see (and overwrite / accumulate onto) earlier blocks' effects."""
    seg = np.array(segment, np.float64)
    pay = np.asarray(payload, np.float64)
    for i in range(nblocks):
        lo = dst_addr + i * stride
        blk = pay[i * blk_words:(i + 1) * blk_words]
        if handler == hd.H_WRITE:
            seg[lo:lo + blk_words] = blk
        elif handler == hd.H_ADD:
            seg[lo:lo + blk_words] += blk
        else:
            raise NotImplementedError(handler)
    return seg


@pytest.mark.parametrize("handler", [hd.H_WRITE, hd.H_ADD])
def test_ingress_strided_overlap_ordered(handler):
    """Regression: stride < blk_words aliases consecutive blocks.  The
    vectorized scatter applies aliased lanes in undefined order (and its
    single up-front gather makes read-modify-write handlers read stale
    values); the ordered variant must match the sequential oracle."""
    ctx = make_ctx(segment_words=64)
    st_ = PgasState.make(64)
    st_ = gc.dataclasses_replace(
        st_, segment=st_.segment.at[:64].set(jnp.arange(64.0) / 10))
    blk_words, nblocks, stride, dst_addr = 3, 4, 1, 5
    pay = jnp.arange(1.0, 1.0 + blk_words * nblocks)
    hdr = am.decode(am.encode(
        type=am.make_type(am.LONG, strided=True), nwords=blk_words * nblocks,
        dst_addr=dst_addr, stride=stride, blk_words=blk_words,
        nblocks=nblocks, handler=handler))
    out = gc.ingress_strided(ctx, st_, hdr, pay, blk_words, nblocks,
                             ordered=True)
    want = _strided_seq_ref(st_.segment, pay, dst_addr, stride, blk_words,
                            nblocks, handler)
    np.testing.assert_allclose(np.asarray(out.segment), want, rtol=1e-6)
    assert int(out.rx_words) == blk_words * nblocks


def test_ingress_strided_ordered_matches_vectorized_when_disjoint():
    """With non-aliasing strides both variants agree (same index map,
    same masking of dynamic nblocks below static capacity)."""
    ctx = make_ctx(segment_words=64)
    st_ = PgasState.make(64)
    pay = jnp.arange(1.0, 7.0)
    hdr = am.decode(am.encode(type=am.make_type(am.LONG, strided=True),
                              nwords=4, dst_addr=5, stride=9, blk_words=2,
                              nblocks=2, handler=hd.H_WRITE))
    vec = gc.ingress_strided(ctx, st_, hdr, pay, 2, 3)
    seq = gc.ingress_strided(ctx, st_, hdr, pay, 2, 3, ordered=True)
    np.testing.assert_array_equal(np.asarray(vec.segment),
                                  np.asarray(seq.segment))


def test_put_long_strided_overlap_autoselect():
    """The op layer detects aliasing strides statically and routes the
    put through the ordered ingress: an aliasing strided put must land
    with sequential last-writer-wins semantics end to end."""
    import jax
    from repro.core import ops
    from repro.core.address_space import GlobalAddressSpace

    ctx = make_ctx(segment_words=64)
    gas = GlobalAddressSpace(ctx)
    blk_words, nblocks, stride, dst_addr = 3, 4, 1, 5
    pay = np.arange(1.0, 1.0 + blk_words * nblocks, dtype=np.float32)

    def prog(st):
        st = ops.put_long_strided(ctx, st, jnp.asarray(pay), [(0, 0)],
                                  dst_addr=dst_addr, stride=stride,
                                  blk_words=blk_words, nblocks=nblocks,
                                  token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    want = _strided_seq_ref(np.zeros(64), pay, dst_addr, stride, blk_words,
                            nblocks, hd.H_WRITE)
    np.testing.assert_allclose(np.asarray(out.segment)[0], want, rtol=1e-6)
    assert int(np.asarray(out.error)[0]) == 0
    # detection: aliasing or traced strides -> ordered; disjoint -> not
    assert ops._strides_may_overlap(1, 3, 4)
    assert ops._strides_may_overlap(-2, 3, 4)
    assert not ops._strides_may_overlap(9, 3, 4)
    assert not ops._strides_may_overlap(1, 3, 1)  # single block never aliases
    seen = []
    jax.jit(lambda s: seen.append(ops._strides_may_overlap(s, 3, 4)) or s)(
        jnp.asarray(9))
    assert seen == [True]  # traced stride: conservatively ordered


def test_mailbox_flush_single_credit_mixed_flags():
    """Credit audit (satellite 3): one flushed stack earns exactly ONE
    credit on the mailbox token, even when the stack mixes handler
    classes and per-message tokens, and a second flush earns a second.
    The per-message tokens never see ack credits."""
    import jax
    from repro.core.address_space import GlobalAddressSpace

    ctx = make_ctx(segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        mb = ctx.mailbox([(0, 0)], msg_words=2, watermark=100, token=6)
        st = mb.send(st, np.asarray([1.0, 2.0]), dst_addr=0, token=1)
        st = mb.send(st, np.asarray([3.0]), dst_addr=4, handler=hd.H_ADD,
                     token=2)
        st = mb.send_signal(st, arg=5, token=9)   # Short row, its own token
        st = mb.flush(st)
        st = mb.send(st, np.asarray([7.0]), dst_addr=8, token=3)
        st = mb.flush(st)
        assert mb.flushes == 2
        return st

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    cred = np.asarray(out.credits)[0]
    assert cred[6] == 2, cred          # exactly one ack credit per flush
    assert cred[9] == 5, cred          # the user Short ran its handler
    assert cred[1] == 0 and cred[2] == 0 and cred[3] == 0, cred
    seg = np.asarray(out.segment)[0]
    np.testing.assert_allclose(seg[0:2], [1, 2])
    np.testing.assert_allclose(seg[4:5], [3])
    np.testing.assert_allclose(seg[8:9], [7])


def test_egress_fifo_pads():
    ctx = make_ctx()
    st_ = PgasState.make(64)
    hdr = am.decode(am.encode(type=am.make_type(am.MEDIUM, fifo=True),
                              nwords=2))
    buf = gc.egress(ctx, st_, hdr, jnp.asarray([5.0, 6.0]), 2)
    np.testing.assert_allclose(buf, [5, 6])
