"""Checkpoint/restart + fault-tolerant training-loop integration."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import ModelConfig, build_model
from repro.optim.adamw import AdamWConfig
from repro.training.elastic import FailureInjector
from repro.training.train import Trainer, TrainerConfig

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=128,
                   dtype=jnp.float32)


def test_save_restore_bitwise():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
        mgr.save(5, tree, extras={"data_step": 5})
        out, extras = mgr.restore(tree, verify=True)
        assert extras["data_step"] == 5
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))


def test_async_save_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in range(5):
            mgr.save_async(s, {"x": jnp.full((4,), float(s))})
        mgr.wait()
        assert mgr.all_steps() == [3, 4]
        out, _ = mgr.restore({"x": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(out["x"]), 4.0)


def test_atomic_no_partial_checkpoints():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": jnp.ones(3)})
        # a stale tmp dir from a crashed save must not be listed
        os.makedirs(os.path.join(d, "step_00000099.tmp"))
        assert mgr.all_steps() == [1]


def _run_steps(trainer, state, pipe, dstep, n, injector=None, mgr=None,
               ckpt_every=0, losses=None):
    step_fn = trainer.make_train_step()
    losses = [] if losses is None else losses   # survives injected failures
    s = state
    while int(s.step) < n:
        cur = int(s.step)
        if injector:
            injector.check(cur)
        batch, dstep = pipe.next_batch(dstep)
        s, m = step_fn(s, batch)
        losses.append(float(m["loss"]))
        if mgr and ckpt_every and int(s.step) % ckpt_every == 0:
            mgr.save(int(s.step), s, extras={"data_step": dstep})
    return s, dstep, losses


def test_failure_restart_resumes_identically():
    """Train 6 steps straight vs train-with-crash-at-4 + restore: the
    loss trajectories and final params must match bitwise-ish (f32)."""
    pipe = TokenPipeline(DataConfig(vocab=128, batch=4, seq=16, seed=9))
    model = build_model(TINY)
    trainer = Trainer(model, AdamWConfig(lr=1e-3), TrainerConfig(donate=False))

    # uninterrupted reference
    s0 = trainer.init_state(jax.random.PRNGKey(0))
    ref_state, _, ref_losses = _run_steps(trainer, s0, pipe, 0, 6)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        inj = FailureInjector({4})
        s = trainer.init_state(jax.random.PRNGKey(0))
        dstep = 0
        losses = []
        try:
            _run_steps(trainer, s, pipe, dstep, 6, injector=inj, mgr=mgr,
                       ckpt_every=2, losses=losses)
            raise AssertionError("injected failure did not fire")
        except RuntimeError:
            pass  # "node failure"
        # launcher-style recovery: restore last good checkpoint + data state
        like = trainer.init_state(jax.random.PRNGKey(0))
        s, extras = mgr.restore(like)
        dstep = extras["data_step"]
        assert int(s.step) == 4 and dstep == 4
        s, dstep, more = _run_steps(trainer, s, pipe, dstep, 6)
        losses = losses[:4] + more

    assert len(losses) == 6
    np.testing.assert_allclose(losses, ref_losses, rtol=1e-6)
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          ref_state.params, s.params)
    assert max(jax.tree.leaves(deltas)) < 1e-6


def test_loss_decreases_overfit():
    pipe = TokenPipeline(DataConfig(vocab=64, batch=4, seq=16, seed=1))
    cfg = TINY
    model = build_model(cfg)
    trainer = Trainer(model, AdamWConfig(lr=3e-3), TrainerConfig(donate=False))
    state = trainer.init_state(jax.random.PRNGKey(0))
    step_fn = trainer.make_train_step()
    batch, _ = pipe.next_batch(0)   # same batch every step: overfit
    losses = []
    for _ in range(30):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_microbatch_grad_equivalence():
    pipe = TokenPipeline(DataConfig(vocab=64, batch=8, seq=8, seed=2))
    model = build_model(TINY)
    batch, _ = pipe.next_batch(0)
    tr1 = Trainer(model, AdamWConfig(lr=1e-3), TrainerConfig(donate=False))
    tr4 = Trainer(model, AdamWConfig(lr=1e-3),
                  TrainerConfig(microbatches=4, donate=False))
    s1 = tr1.init_state(jax.random.PRNGKey(0))
    s4 = tr4.init_state(jax.random.PRNGKey(0))
    o1, m1 = tr1.make_train_step()(s1, batch)
    o4, m4 = tr4.make_train_step()(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          o1.params, o4.params)
    # reduction-order noise through Adam's rsqrt: ~1e-5-scale is expected
    assert max(jax.tree.leaves(deltas)) < 1e-4
