"""Actor-mailbox semantic + collective-budget checks.

Run by tests/test_actors.py in a subprocess with 8 host devices.  Three
properties of the actor layer are *measured* here, not believed:

* flush semantics — a stack mixing Long writes, Long accumulates, and
  Short signals dispatches every row correctly through the scanned
  mixed-class ingress, and an acked flush earns exactly one credit on
  the mailbox token;
* the headline budget — 1024 4-word sends to one destination compile
  to <= 2 collective-permutes (1 fused stack + 1 coalesced reply),
  vs 1024+ in the message-at-a-time model;
* reply coalescing — puts routed through a ReplyMailbox pay one credit
  collective per (destination, token) at flush, not one per put.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors import Mailbox, MultiMailbox, ReplyMailbox
from repro.core import handlers as hd, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.launch.hlo_analysis import parse_collectives
from repro.runtime import TCP, UDP
from repro.runtime.topology import make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]


def cp_count(gas, prog):
    state0 = gas.make_global_state()
    hlo = jax.jit(gas.spmd(prog)).lower(state0).compile().as_text()
    return parse_collectives(hlo).ops.get("collective-permute", 0.0)


def check(name, ok, detail=""):
    assert ok, f"{name}: FAILED {detail}"
    print(f"[actors] {name} ok {detail}")


def make(transport, segment_words):
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=segment_words)
    return ctx, GlobalAddressSpace(ctx)


def sequential_schedule_oracle(schedule, segment_words):
    """Numpy reference semantics for a put/wait/barrier schedule.

    Row kinds::

        ("put",       start, words, value, token, acked[, group])
        ("put_defer", start, words, value, token[, group])
        ("piggyback", token)
        ("drain",     token)
        ("wait",      token, n)
        ("barrier",)

    ``put_defer`` is an acked put whose ack is *ledgered at the
    receiver* instead of shipped (the reply-piggybacking protocol);
    ``piggyback`` models the later reverse-link data packet whose header
    lane carries that token's ledgered acks home, and ``drain`` the
    explicit loop-exit ``drain_deferred_acks`` — both move the whole
    ledger slot into the sender's credits.  Rows sharing a ``group`` id
    model one ``put_long_multi`` call: their stacks cross the links in
    ONE collective and apply in row order, so same-group rows are never
    mutually reorderable (overlapping same-group intervals raise
    ``VectoredAliasError`` at trace time anyway).

    Executes the writes in program order, then independently derives
    what the analyzer should report — this is jax-free and shares no
    code with :mod:`repro.analysis.rules`, so the property test in
    tests/test_comm_lint.py can cross-check race verdicts against it.

    Returns a dict with:

    * ``segment`` — final numpy segment in program order;
    * ``unordered_overlaps`` — (i, j) put pairs whose arrival order the
      transport may legally swap (no barrier; no wait on put i's ack
      token between them — for a deferred ack the wait only orders once
      a piggyback/drain grant for that token sits between put and wait)
      and whose intervals overlap;
    * ``divergent`` — the subset of those pairs where delaying put i's
      arrival until after put j actually changes final memory (a pair
      can be non-divergent yet racy when a later put shadows it);
    * ``underflow_events`` — schedule indices of waits that drain more
      credits than were issued by then;
    * ``leaked_tokens`` — tokens with credits left at the end;
    * ``stranded_acks`` — tokens whose receiver ledger is nonzero at the
      end: no reverse-link packet piggybacked them and no drain shipped
      them, so the sender's wait can never be satisfied.
    """
    n = len(schedule)

    def norm(ev):
        kind = ev[0]
        if kind == "put":
            return {"kind": "put", "start": ev[1], "words": ev[2],
                    "value": ev[3], "token": ev[4], "acked": ev[5],
                    "defer": False,
                    "group": ev[6] if len(ev) > 6 else None}
        if kind == "put_defer":
            return {"kind": "put", "start": ev[1], "words": ev[2],
                    "value": ev[3], "token": ev[4], "acked": True,
                    "defer": True,
                    "group": ev[5] if len(ev) > 5 else None}
        if kind in ("piggyback", "drain"):
            return {"kind": "grant", "token": ev[1]}
        if kind == "wait":
            return {"kind": "wait", "token": ev[1], "n": ev[2]}
        return {"kind": "barrier"}

    rows = [norm(ev) for ev in schedule]

    def run(order):
        seg = np.zeros(segment_words, np.float64)
        for idx in order:
            r = rows[idx]
            if r["kind"] == "put":
                seg[r["start"]:r["start"] + r["words"]] = r["value"]
        return seg

    base = run(range(n))

    credits: dict = {}
    ledger: dict = {}
    underflow_events = []
    for idx, r in enumerate(rows):
        if r["kind"] == "put":
            if r["defer"]:
                ledger[r["token"]] = ledger.get(r["token"], 0) + 1
            elif r["acked"]:
                credits[r["token"]] = credits.get(r["token"], 0) + 1
        elif r["kind"] == "grant":
            credits[r["token"]] = (credits.get(r["token"], 0)
                                   + ledger.pop(r["token"], 0))
        elif r["kind"] == "wait":
            tok, cnt = r["token"], r["n"]
            if cnt > credits.get(tok, 0):
                underflow_events.append(idx)
            credits[tok] = credits.get(tok, 0) - cnt
    leaked = sorted(t for t, c in credits.items() if c > 0)
    stranded = sorted(t for t, c in ledger.items() if c > 0)

    def ordered_before(i, j):
        ri = rows[i]
        for k in range(i + 1, j):
            rk = rows[k]
            if rk["kind"] == "barrier":
                return True
            if rk["kind"] == "wait" and ri["acked"] \
                    and rk["token"] == ri["token"]:
                if not ri["defer"]:
                    return True      # i's ack was consumed: ordered
                # a deferred ack reaches the wait only via a grant
                # (piggyback lane or drain) issued after the put
                if any(rows[g]["kind"] == "grant"
                       and rows[g]["token"] == ri["token"]
                       for g in range(i + 1, k)):
                    return True
        return False

    unordered, divergent = [], []
    for i in range(n):
        if rows[i]["kind"] != "put":
            continue
        for j in range(i + 1, n):
            if rows[j]["kind"] != "put":
                continue
            si, wi = rows[i]["start"], rows[i]["words"]
            sj, wj = rows[j]["start"], rows[j]["words"]
            if not (si < sj + wj and sj < si + wi):
                continue
            if rows[i]["group"] is not None \
                    and rows[i]["group"] == rows[j]["group"]:
                continue             # one collective: stack order fixed
            if ordered_before(i, j):
                continue
            unordered.append((i, j))
            order = [k for k in range(n) if k != i]
            order.insert(order.index(j) + 1, i)
            if not np.array_equal(run(order), base):
                divergent.append((i, j))
    return {"segment": base, "unordered_overlaps": unordered,
            "divergent": divergent, "underflow_events": underflow_events,
            "leaked_tokens": leaked, "stranded_acks": stranded}


def test_mailbox_mixed_stack_semantics():
    """Long writes + Long adds + Short signals in ONE flush, correct
    per-row dispatch, one credit per flush on the mailbox token."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=4, watermark=1024, token=5)
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        for i in range(6):
            st = mb.send(st, me1 * (jnp.arange(4.0) + 1) + 100 * i,
                         dst_addr=8 * i)
        st = mb.send(st, jnp.full((4,), 0.5), dst_addr=0, handler=hd.H_ADD)
        st = mb.send_signal(st, handler=hd.H_ADD, arg=3, token=7)
        st = mb.flush(st)
        assert mb.flushes == 1 and mb.msgs_sent == 8 and mb.pending == 0
        return ops.wait_replies(ctx, st, token=5, n=1)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)
    cred = np.asarray(out.credits)
    for k in range(N):
        pred = (k - 1) % N            # my sender on the ring
        for i in range(6):
            want = (pred + 1) * (np.arange(4.0) + 1) + 100 * i
            if i == 0:
                want = want + 0.5     # the H_ADD row aliases dst_addr 0
            np.testing.assert_allclose(seg[k, 8 * i:8 * i + 4], want,
                                       err_msg=f"kernel {k} row {i}")
        assert cred[k, 7] == 3, (k, cred[k])
        assert cred[k, 5] == 0, (k, cred[k])   # exactly 1 ack, drained
    assert not np.asarray(out.error).any()
    check("mailbox/mixed-stack semantics", True, f"(8 msgs, {N} kernels)")


def test_1024_sends_two_collectives():
    """The acceptance criterion: 1024 4-word mailbox sends to one
    destination compile to <= 2 collectives (stack + coalesced reply)."""
    n_msgs, w = 1024, 4
    ctx, gas = make(TCP, n_msgs * w + 64)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=w, watermark=1 << 20, token=1)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        st = mb.flush(st)
        return ops.wait_replies(ctx, st, token=1, n=1)

    cps = cp_count(gas, prog)
    check("mailbox/1024-sends budget", cps <= 2,
          f"({cps:.0f} collective-permutes <= 2; "
          f"{n_msgs / max(cps, 1):.0f} msgs/collective)")

    # and the async transport drops the reply: one collective total
    ctx_u, gas_u = make(UDP, n_msgs * w + 64)

    def prog_u(st):
        mb = Mailbox(ctx_u, RING, msg_words=w, watermark=1 << 20)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        return mb.flush(st)

    cps_u = cp_count(gas_u, prog_u)
    check("mailbox/1024-sends async budget", cps_u <= 1,
          f"({cps_u:.0f} collective-permutes <= 1)")


def test_multi_mailbox_grouped_flush():
    """Two disjoint destination patterns flush as ONE collective + ONE
    counted reply, with correct per-pattern delivery and one credit per
    pattern on the mailbox token."""
    ctx, gas = make(TCP, 256)
    even = [(i, i + 1) for i in range(0, N, 2)]
    odd = [(i, (i + 1) % N) for i in range(1, N, 2)]

    def prog(st):
        mmb = MultiMailbox(ctx, [even, odd], msg_words=4,
                           watermark=1 << 20, token=6)
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        ones = jnp.ones((4,), jnp.float32)
        for i in range(3):
            st = mmb.send(st, 0, (me1 * 10 + i) * ones, dst_addr=4 * i)
            st = mmb.send(st, 1, -(me1 * 10 + i) * ones,
                          dst_addr=16 + 4 * i)
        st = mmb.flush(st)
        assert mmb.flushes == 1 and mmb.pending == 0 and mmb.msgs_sent == 6
        assert mmb.groups == [[0, 1]]        # the patterns merged
        # every kernel SENDS on exactly one of the two rings (masked out
        # of the other), so the counted group reply returns one credit
        return ops.wait_replies(ctx, st, token=6, n=1)

    cps = cp_count(gas, prog)
    check("multi-mailbox/flush budget", cps == 2,
          f"({cps:.0f} collective-permutes == 2 for 2 patterns)")
    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)
    for k in range(N):
        src1 = ((k - 1) % N) + 1             # my sender on either ring
        sign = 1.0 if k % 2 == 1 else -1.0   # odd kernels: even-ring rows
        base = 0 if k % 2 == 1 else 16
        for i in range(3):
            np.testing.assert_allclose(seg[k, base + 4 * i:base + 4 * i + 4],
                                       sign * (src1 * 10 + i),
                                       err_msg=f"kernel {k} msg {i}")
    assert not np.asarray(out.error).any()
    assert (np.asarray(out.credits) == 0).all()
    check("multi-mailbox/grouped-flush semantics", True,
          f"(2 patterns x 3 msgs, {N} kernels)")


def test_watermark_autoflush():
    """send() flushes automatically at the watermark; each flush is its
    own collective and its own credit."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=2, watermark=4, token=3)
        for i in range(10):
            st = mb.send(st, np.asarray([float(i), 0.0]), dst_addr=2 * i)
        assert mb.flushes == 2 and mb.pending == 2
        st = mb.flush(st)
        assert mb.flushes == 3
        return ops.wait_replies(ctx, st, token=3, n=3)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)
    for k in range(N):
        np.testing.assert_allclose(seg[k, 0:20:2], np.arange(10.0))
    assert not np.asarray(out.error).any()
    check("mailbox/watermark autoflush", True, "(10 sends @ watermark 4)")


def test_reply_mailbox_coalesces_acks():
    """K acked puts with reply_via pay ONE credit collective per
    (destination, token) at flush, and the credits still arrive."""
    ctx, gas = make(TCP, 256)

    def prog_coalesced(st):
        rmb = ReplyMailbox(ctx)
        pay = jnp.arange(4.0)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=0, token=2,
                          reply_via=rmb)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=8, token=2,
                          reply_via=rmb)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=16, token=2,
                          reply_via=rmb)
        assert rmb.pending == 3
        st = rmb.flush(st)
        return ops.wait_replies(ctx, st, token=2, n=3)

    def prog_baseline(st):
        pay = jnp.arange(4.0)
        for a in (0, 8, 16):
            st = ops.put_long(ctx, st, pay, RING, dst_addr=a, token=2)
        return ops.wait_replies(ctx, st, token=2, n=3)

    out = jax.jit(gas.spmd(prog_coalesced))(gas.make_global_state())
    assert not np.asarray(out.error).any()
    assert (np.asarray(out.credits) == 0).all()
    cps = cp_count(gas, prog_coalesced)
    cps_base = cp_count(gas, prog_baseline)
    # 3 data + 1 coalesced credit return vs 3 data + 3 replies
    check("reply-mailbox coalescing", cps < cps_base,
          f"({cps:.0f} < {cps_base:.0f} collective-permutes)")


def test_async_put_skips_reply_collective():
    """The credit-audit fix: a statically-async put on an acked
    transport no longer ships a wasted all-NOP reply."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        return ops.put_long(ctx, st, jnp.arange(4.0), RING, dst_addr=0,
                            asynchronous=True)

    cps = cp_count(gas, prog)
    check("async-put reply elision", cps == 1,
          f"({cps:.0f} collective-permutes == 1)")


def main():
    test_mailbox_mixed_stack_semantics()
    test_1024_sends_two_collectives()
    test_multi_mailbox_grouped_flush()
    test_watermark_autoflush()
    test_reply_mailbox_coalesces_acks()
    test_async_put_skips_reply_collective()
    print("ACTOR_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
