"""Actor-mailbox semantic + collective-budget checks.

Run by tests/test_actors.py in a subprocess with 8 host devices.  Three
properties of the actor layer are *measured* here, not believed:

* flush semantics — a stack mixing Long writes, Long accumulates, and
  Short signals dispatches every row correctly through the scanned
  mixed-class ingress, and an acked flush earns exactly one credit on
  the mailbox token;
* the headline budget — 1024 4-word sends to one destination compile
  to <= 2 collective-permutes (1 fused stack + 1 coalesced reply),
  vs 1024+ in the message-at-a-time model;
* reply coalescing — puts routed through a ReplyMailbox pay one credit
  collective per (destination, token) at flush, not one per put.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors import Mailbox, ReplyMailbox
from repro.core import handlers as hd, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.launch.hlo_analysis import parse_collectives
from repro.runtime import TCP, UDP
from repro.runtime.topology import make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]


def cp_count(gas, prog):
    state0 = gas.make_global_state()
    hlo = jax.jit(gas.spmd(prog)).lower(state0).compile().as_text()
    return parse_collectives(hlo).ops.get("collective-permute", 0.0)


def check(name, ok, detail=""):
    assert ok, f"{name}: FAILED {detail}"
    print(f"[actors] {name} ok {detail}")


def make(transport, segment_words):
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=segment_words)
    return ctx, GlobalAddressSpace(ctx)


def sequential_schedule_oracle(schedule, segment_words):
    """Numpy reference semantics for a put/wait/barrier schedule.

    ``schedule`` rows are ``("put", start, words, value, token, acked)``,
    ``("wait", token, n)``, or ``("barrier",)``.  Executes the writes in
    program order, then independently derives what the analyzer should
    report — this is jax-free and shares no code with
    :mod:`repro.analysis.rules`, so the property test in
    tests/test_comm_lint.py can cross-check race verdicts against it.

    Returns a dict with:

    * ``segment`` — final numpy segment in program order;
    * ``unordered_overlaps`` — (i, j) put pairs whose arrival order the
      transport may legally swap (no barrier, no wait on put i's ack
      token between them) and whose intervals overlap;
    * ``divergent`` — the subset of those pairs where delaying put i's
      arrival until after put j actually changes final memory (a pair
      can be non-divergent yet racy when a later put shadows it);
    * ``underflow_events`` — schedule indices of waits that drain more
      credits than were issued by then;
    * ``leaked_tokens`` — tokens with credits left at the end.
    """
    n = len(schedule)

    def run(order):
        seg = np.zeros(segment_words, np.float64)
        for idx in order:
            ev = schedule[idx]
            if ev[0] == "put":
                _, start, words, value, _tok, _acked = ev
                seg[start:start + words] = value
        return seg

    base = run(range(n))

    credits: dict = {}
    underflow_events = []
    for idx, ev in enumerate(schedule):
        if ev[0] == "put" and ev[5]:
            credits[ev[4]] = credits.get(ev[4], 0) + 1
        elif ev[0] == "wait":
            _, tok, cnt = ev
            if cnt > credits.get(tok, 0):
                underflow_events.append(idx)
            credits[tok] = credits.get(tok, 0) - cnt
    leaked = sorted(t for t, c in credits.items() if c > 0)

    unordered, divergent = [], []
    for i in range(n):
        if schedule[i][0] != "put":
            continue
        for j in range(i + 1, n):
            between = schedule[i + 1:j]
            if any(e[0] == "barrier" for e in between):
                break            # i is ordered before everything later
            if schedule[i][5] and any(
                    e[0] == "wait" and e[1] == schedule[i][4]
                    for e in between):
                break            # i's ack was consumed: ordered
            if schedule[j][0] != "put":
                continue
            si, wi = schedule[i][1], schedule[i][2]
            sj, wj = schedule[j][1], schedule[j][2]
            if not (si < sj + wj and sj < si + wi):
                continue
            unordered.append((i, j))
            order = [k for k in range(n) if k != i]
            order.insert(order.index(j) + 1, i)
            if not np.array_equal(run(order), base):
                divergent.append((i, j))
    return {"segment": base, "unordered_overlaps": unordered,
            "divergent": divergent, "underflow_events": underflow_events,
            "leaked_tokens": leaked}


def test_mailbox_mixed_stack_semantics():
    """Long writes + Long adds + Short signals in ONE flush, correct
    per-row dispatch, one credit per flush on the mailbox token."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=4, watermark=1024, token=5)
        me1 = (ctx.my_id() + 1).astype(jnp.float32)
        for i in range(6):
            st = mb.send(st, me1 * (jnp.arange(4.0) + 1) + 100 * i,
                         dst_addr=8 * i)
        st = mb.send(st, jnp.full((4,), 0.5), dst_addr=0, handler=hd.H_ADD)
        st = mb.send_signal(st, handler=hd.H_ADD, arg=3, token=7)
        st = mb.flush(st)
        assert mb.flushes == 1 and mb.msgs_sent == 8 and mb.pending == 0
        return ops.wait_replies(ctx, st, token=5, n=1)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)
    cred = np.asarray(out.credits)
    for k in range(N):
        pred = (k - 1) % N            # my sender on the ring
        for i in range(6):
            want = (pred + 1) * (np.arange(4.0) + 1) + 100 * i
            if i == 0:
                want = want + 0.5     # the H_ADD row aliases dst_addr 0
            np.testing.assert_allclose(seg[k, 8 * i:8 * i + 4], want,
                                       err_msg=f"kernel {k} row {i}")
        assert cred[k, 7] == 3, (k, cred[k])
        assert cred[k, 5] == 0, (k, cred[k])   # exactly 1 ack, drained
    assert not np.asarray(out.error).any()
    check("mailbox/mixed-stack semantics", True, f"(8 msgs, {N} kernels)")


def test_1024_sends_two_collectives():
    """The acceptance criterion: 1024 4-word mailbox sends to one
    destination compile to <= 2 collectives (stack + coalesced reply)."""
    n_msgs, w = 1024, 4
    ctx, gas = make(TCP, n_msgs * w + 64)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=w, watermark=1 << 20, token=1)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        st = mb.flush(st)
        return ops.wait_replies(ctx, st, token=1, n=1)

    cps = cp_count(gas, prog)
    check("mailbox/1024-sends budget", cps <= 2,
          f"({cps:.0f} collective-permutes <= 2; "
          f"{n_msgs / max(cps, 1):.0f} msgs/collective)")

    # and the async transport drops the reply: one collective total
    ctx_u, gas_u = make(UDP, n_msgs * w + 64)

    def prog_u(st):
        mb = Mailbox(ctx_u, RING, msg_words=w, watermark=1 << 20)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        return mb.flush(st)

    cps_u = cp_count(gas_u, prog_u)
    check("mailbox/1024-sends async budget", cps_u <= 1,
          f"({cps_u:.0f} collective-permutes <= 1)")


def test_watermark_autoflush():
    """send() flushes automatically at the watermark; each flush is its
    own collective and its own credit."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        mb = Mailbox(ctx, RING, msg_words=2, watermark=4, token=3)
        for i in range(10):
            st = mb.send(st, np.asarray([float(i), 0.0]), dst_addr=2 * i)
        assert mb.flushes == 2 and mb.pending == 2
        st = mb.flush(st)
        assert mb.flushes == 3
        return ops.wait_replies(ctx, st, token=3, n=3)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)
    for k in range(N):
        np.testing.assert_allclose(seg[k, 0:20:2], np.arange(10.0))
    assert not np.asarray(out.error).any()
    check("mailbox/watermark autoflush", True, "(10 sends @ watermark 4)")


def test_reply_mailbox_coalesces_acks():
    """K acked puts with reply_via pay ONE credit collective per
    (destination, token) at flush, and the credits still arrive."""
    ctx, gas = make(TCP, 256)

    def prog_coalesced(st):
        rmb = ReplyMailbox(ctx)
        pay = jnp.arange(4.0)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=0, token=2,
                          reply_via=rmb)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=8, token=2,
                          reply_via=rmb)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=16, token=2,
                          reply_via=rmb)
        assert rmb.pending == 3
        st = rmb.flush(st)
        return ops.wait_replies(ctx, st, token=2, n=3)

    def prog_baseline(st):
        pay = jnp.arange(4.0)
        for a in (0, 8, 16):
            st = ops.put_long(ctx, st, pay, RING, dst_addr=a, token=2)
        return ops.wait_replies(ctx, st, token=2, n=3)

    out = jax.jit(gas.spmd(prog_coalesced))(gas.make_global_state())
    assert not np.asarray(out.error).any()
    assert (np.asarray(out.credits) == 0).all()
    cps = cp_count(gas, prog_coalesced)
    cps_base = cp_count(gas, prog_baseline)
    # 3 data + 1 coalesced credit return vs 3 data + 3 replies
    check("reply-mailbox coalescing", cps < cps_base,
          f"({cps:.0f} < {cps_base:.0f} collective-permutes)")


def test_async_put_skips_reply_collective():
    """The credit-audit fix: a statically-async put on an acked
    transport no longer ships a wasted all-NOP reply."""
    ctx, gas = make(TCP, 256)

    def prog(st):
        return ops.put_long(ctx, st, jnp.arange(4.0), RING, dst_addr=0,
                            asynchronous=True)

    cps = cp_count(gas, prog)
    check("async-put reply elision", cps == 1,
          f"({cps:.0f} collective-permutes == 1)")


def main():
    test_mailbox_mixed_stack_semantics()
    test_1024_sends_two_collectives()
    test_watermark_autoflush()
    test_reply_mailbox_coalesces_acks()
    test_async_put_skips_reply_collective()
    print("ACTOR_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
