"""Property tests for the AM wire format (paper Sec. III-A)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import am

field_vals = st.integers(min_value=0, max_value=2**20)


@settings(max_examples=50, deadline=None)
@given(
    msg_class=st.sampled_from([am.NOP, am.SHORT, am.MEDIUM, am.LONG]),
    src=field_vals, dst=field_vals, nwords=field_vals,
    dst_addr=field_vals, src_addr=field_vals,
    handler=st.integers(0, 31), token=st.integers(0, 15),
    asynchronous=st.booleans(), get=st.booleans(), fifo=st.booleans(),
    strided=st.booleans(), vectored=st.booleans(), reply=st.booleans(),
)
def test_encode_decode_roundtrip(msg_class, src, dst, nwords, dst_addr,
                                 src_addr, handler, token, asynchronous,
                                 get, fifo, strided, vectored, reply):
    t = am.make_type(msg_class, asynchronous=asynchronous, get=get,
                     fifo=fifo, strided=strided, vectored=vectored,
                     reply=reply)
    hdr = am.encode(type=t, src=src, dst=dst, nwords=nwords,
                    dst_addr=dst_addr, src_addr=src_addr, handler=handler,
                    token=token)
    h = am.decode(hdr)
    assert int(h.msg_class) == msg_class
    assert int(h.src) == src and int(h.dst) == dst
    assert int(h.nwords) == nwords
    assert int(h.dst_addr) == dst_addr and int(h.src_addr) == src_addr
    assert int(h.handler) == handler and int(h.token) == token
    assert bool(h.flag(am.FLAG_ASYNC)) == asynchronous
    assert bool(h.flag(am.FLAG_GET)) == get
    assert bool(h.flag(am.FLAG_FIFO)) == fifo
    assert bool(h.flag(am.FLAG_STRIDED)) == strided
    assert bool(h.flag(am.FLAG_VECTORED)) == vectored
    assert bool(h.flag(am.FLAG_REPLY)) == reply


def test_zero_header_is_nop():
    h = am.decode(jnp.zeros((am.HDR_WORDS,), jnp.int32))
    assert bool(am.is_nop(h))
    assert not bool(h.flag(am.FLAG_ASYNC))


def test_reply_for_targets_source():
    hdr = am.encode(type=am.make_type(am.LONG), src=3, dst=7, token=5)
    rep = am.decode(am.reply_for(am.decode(hdr)))
    assert int(rep.src) == 7 and int(rep.dst) == 3
    assert int(rep.token) == 5
    assert bool(rep.flag(am.FLAG_REPLY))
    assert bool(rep.flag(am.FLAG_ASYNC))  # replies must not trigger replies


def test_unknown_field_rejected():
    with pytest.raises(ValueError):
        am.encode(bogus=1)


def test_header_width():
    hdr = am.encode(type=am.make_type(am.SHORT))
    assert hdr.shape == (am.HDR_WORDS,)
    assert hdr.dtype == jnp.int32
