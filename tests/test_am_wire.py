"""Property tests for the AM wire format (paper Sec. III-A)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import am

field_vals = st.integers(min_value=0, max_value=2**20)


@settings(max_examples=50, deadline=None)
@given(
    msg_class=st.sampled_from([am.NOP, am.SHORT, am.MEDIUM, am.LONG]),
    src=field_vals, dst=field_vals, nwords=field_vals,
    dst_addr=field_vals, src_addr=field_vals,
    handler=st.integers(0, 31), token=st.integers(0, 15),
    asynchronous=st.booleans(), get=st.booleans(), fifo=st.booleans(),
    strided=st.booleans(), vectored=st.booleans(), reply=st.booleans(),
)
def test_encode_decode_roundtrip(msg_class, src, dst, nwords, dst_addr,
                                 src_addr, handler, token, asynchronous,
                                 get, fifo, strided, vectored, reply):
    t = am.make_type(msg_class, asynchronous=asynchronous, get=get,
                     fifo=fifo, strided=strided, vectored=vectored,
                     reply=reply)
    hdr = am.encode(type=t, src=src, dst=dst, nwords=nwords,
                    dst_addr=dst_addr, src_addr=src_addr, handler=handler,
                    token=token)
    h = am.decode(hdr)
    assert int(h.msg_class) == msg_class
    assert int(h.src) == src and int(h.dst) == dst
    assert int(h.nwords) == nwords
    assert int(h.dst_addr) == dst_addr and int(h.src_addr) == src_addr
    assert int(h.handler) == handler and int(h.token) == token
    assert bool(h.flag(am.FLAG_ASYNC)) == asynchronous
    assert bool(h.flag(am.FLAG_GET)) == get
    assert bool(h.flag(am.FLAG_FIFO)) == fifo
    assert bool(h.flag(am.FLAG_STRIDED)) == strided
    assert bool(h.flag(am.FLAG_VECTORED)) == vectored
    assert bool(h.flag(am.FLAG_REPLY)) == reply


def test_zero_header_is_nop():
    h = am.decode(jnp.zeros((am.HDR_WORDS,), jnp.int32))
    assert bool(am.is_nop(h))
    assert not bool(h.flag(am.FLAG_ASYNC))


def test_reply_for_targets_source():
    hdr = am.encode(type=am.make_type(am.LONG), src=3, dst=7, token=5)
    rep = am.decode(am.reply_for(am.decode(hdr)))
    assert int(rep.src) == 7 and int(rep.dst) == 3
    assert int(rep.token) == 5
    assert bool(rep.flag(am.FLAG_REPLY))
    assert bool(rep.flag(am.FLAG_ASYNC))  # replies must not trigger replies


def test_unknown_field_rejected():
    with pytest.raises(ValueError):
        am.encode(bogus=1)


def test_header_width():
    hdr = am.encode(type=am.make_type(am.SHORT))
    assert hdr.shape == (am.HDR_WORDS,)
    assert hdr.dtype == jnp.int32


# -- fused packets ------------------------------------------------------------

def test_fused_packet_roundtrip_bit_exact():
    """header ++ payload fuse into ONE int32 packet and split back
    bit-exactly — even for payload bit patterns that are NaNs/denormals
    as float32 (bitcast, not value conversion)."""
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2**32, size=37, dtype=np.uint32)
    pay = jnp.asarray(bits.view(np.float32))
    hdr = am.encode(type=am.make_type(am.LONG, fifo=True), src=1, dst=2,
                    nwords=37, dst_addr=11, token=3)
    pkt = am.pack_packet(hdr, pay)
    assert pkt.dtype == jnp.int32
    assert pkt.shape == (am.HDR_WORDS + 37,)
    h2, p2 = am.unpack_packet(pkt, pay.dtype)
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(hdr))
    assert np.asarray(p2).tobytes() == np.asarray(pay).tobytes()


def test_fused_packet_extra_section():
    """Vectored AMs carry their address list as an int32 extra section
    between header and payload: header ++ addrs ++ payload."""
    pay = jnp.asarray([1.5, -2.25, 3.0], jnp.float32)
    addrs = jnp.asarray([50, 60, 70], jnp.int32)
    hdr = am.encode(type=am.make_type(am.LONG, vectored=True), nwords=3,
                    nblocks=3)
    pkt = am.pack_packet(hdr, pay, extra=addrs)
    assert pkt.shape == (am.HDR_WORDS + 3 + 3,)
    h2, e2, p2 = am.unpack_packet(pkt, pay.dtype, n_extra=3)
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(hdr))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(addrs))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(pay))


def test_fused_packet_batched_rows():
    """A segmentation plan fuses row-wise: (nseg, HDR + W) int32."""
    nseg, W = 4, 8
    hdrs = am.encode_batch(nseg, type=am.make_type(am.LONG),
                           nwords=jnp.full((nseg,), W), seq=jnp.arange(nseg) * W)
    pay = jnp.arange(nseg * W, dtype=jnp.float32).reshape(nseg, W)
    pkt = am.pack_packet(hdrs, pay)
    assert pkt.shape == (nseg, am.HDR_WORDS + W)
    h2, p2 = am.unpack_packet(pkt, pay.dtype)
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(hdrs))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(pay))


def test_encode_batch_broadcast_and_rows():
    hdrs = am.encode_batch(3, type=am.make_type(am.MEDIUM), src=7,
                           nwords=jnp.asarray([16, 16, 2]))
    assert hdrs.shape == (3, am.HDR_WORDS)
    for r in range(3):
        h = am.decode(hdrs[r])
        assert int(h.src) == 7
    assert [int(am.decode(hdrs[r]).nwords) for r in range(3)] == [16, 16, 2]
    with pytest.raises(ValueError):
        am.encode_batch(2, bogus=1)


_RT_DTYPES = (np.float32, np.int32, np.uint32)


@settings(max_examples=40, deadline=None)
@given(dtype_i=st.integers(0, len(_RT_DTYPES) - 1),
       n_extra=st.integers(0, 4),
       nseg=st.integers(1, 4),
       width=st.integers(1, 9))
def test_pack_unpack_roundtrip_property(dtype_i, n_extra, nseg, width):
    """Property: pack_packet/unpack_packet round-trip BIT-exactly over
    dtype x extra-section length x segment count x payload width —
    including payload bit patterns that are NaN/denormal as f32 (the
    wire is a bitcast, never a value conversion).  nseg == 1 exercises
    the unbatched single-packet shape, nseg > 1 the (nseg, ...) stack."""
    dtype = _RT_DTYPES[dtype_i]
    rng = np.random.default_rng(
        1 + dtype_i * 1000 + n_extra * 100 + nseg * 10 + width)
    pay_np = rng.integers(0, 2**32, size=(nseg, width),
                          dtype=np.uint32).view(dtype)
    extra_np = rng.integers(0, 2**20, size=(nseg, n_extra), dtype=np.int32)
    t = am.make_type(am.LONG, fifo=True, vectored=n_extra > 0)
    hdr = am.encode_batch(nseg, type=t, nwords=jnp.full((nseg,), width),
                          nblocks=n_extra, seq=jnp.arange(nseg) * width)
    pay, extra = jnp.asarray(pay_np), jnp.asarray(extra_np)
    if nseg == 1:  # cover the unbatched packet shape too
        hdr, pay, extra = hdr[0], pay[0], extra[0]
    pkt = am.pack_packet(hdr, pay, extra if n_extra else None)
    assert pkt.dtype == jnp.int32
    assert pkt.shape[-1] == am.HDR_WORDS + n_extra + width
    out = am.unpack_packet(pkt, pay.dtype, n_extra)
    h2, e2, p2 = out if n_extra else (out[0], None, out[1])
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(hdr))
    assert np.asarray(p2).tobytes() == pay_np.tobytes()
    assert np.asarray(p2).dtype == pay_np.dtype
    if n_extra:
        np.testing.assert_array_equal(np.asarray(e2), extra_np.reshape(e2.shape))


def test_wire_dtype_guard():
    assert am.wire_dtype_ok(jnp.float32) and am.wire_dtype_ok(jnp.int32)
    assert not am.wire_dtype_ok(jnp.bfloat16)
    with pytest.raises(TypeError):
        am.to_wire(jnp.zeros((4,), jnp.bfloat16))
