"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True: the kernel body executes on CPU; TPU is the target)."""

import jax.numpy as jnp

from repro.runtime.jax_compat import shard_map
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.am_pack import am_pack, am_pack_ref, am_unpack, am_unpack_ref
from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.jacobi import jacobi_step, jacobi_step_ref

RNG = np.random.default_rng(42)


# -- jacobi -------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(16, 128), (64, 128), (256, 256), (128, 512),
                                 (40, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi_matches_ref(m, n, dtype):
    x = jnp.asarray(RNG.standard_normal((m, n)), dtype)
    got = jacobi_step(x, use_pallas=True)
    want = jacobi_step_ref(x)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_jacobi_boundary_fixed():
    x = jnp.asarray(RNG.standard_normal((32, 128)), jnp.float32)
    out = jacobi_step(x, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out)[0], np.asarray(x)[0])
    np.testing.assert_array_equal(np.asarray(out)[-1], np.asarray(x)[-1])
    np.testing.assert_array_equal(np.asarray(out)[:, 0], np.asarray(x)[:, 0])
    np.testing.assert_array_equal(np.asarray(out)[:, -1], np.asarray(x)[:, -1])


def test_jacobi_converges_to_laplace():
    """1024 iterations drive the interior toward the harmonic solution."""
    n = 32
    x = jnp.zeros((n, 128), jnp.float32).at[0, :].set(1.0)
    from repro.kernels.jacobi import jacobi_run
    out = jacobi_run(x, 512, use_pallas=False)
    # top-adjacent interior rows approach the linear profile; just check
    # monotone decay and boundedness
    col = np.asarray(out)[:, 64]
    assert col[0] == 1.0
    assert np.all(np.diff(col[:n // 2]) <= 1e-6)
    assert np.all((col >= -1e-6) & (col <= 1.0 + 1e-6))


# -- am_pack ------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    addr=st.integers(0, 50),
    stride=st.integers(8, 40),
    blk=st.integers(1, 8),
    nblocks=st.integers(1, 6),
)
def test_am_pack_property(addr, stride, blk, nblocks):
    blk = min(blk, stride)   # non-overlapping blocks
    seg = jnp.asarray(RNG.standard_normal(512), jnp.float32)
    got = am_pack(seg, addr, stride, blk, nblocks)
    want = am_pack_ref(seg, addr, stride, blk, nblocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    addr=st.integers(0, 50),
    stride=st.integers(8, 40),
    blk=st.integers(1, 8),
    nblocks=st.integers(1, 6),
)
def test_am_unpack_property(addr, stride, blk, nblocks):
    blk = min(blk, stride)
    seg = jnp.asarray(RNG.standard_normal(512), jnp.float32)
    pay = jnp.asarray(RNG.standard_normal(blk * nblocks), jnp.float32)
    got = am_unpack(seg, pay, addr, stride, blk, nblocks)
    want = am_unpack_ref(seg, pay, addr, stride, blk, nblocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_pack_unpack_roundtrip():
    seg = jnp.asarray(RNG.standard_normal(1024), jnp.float32)
    pay = am_pack(seg, 100, 64, 32, 8)
    seg2 = am_unpack(jnp.zeros_like(seg), pay, 100, 64, 32, 8)
    idx = (100 + 64 * np.arange(8)[:, None] + np.arange(32)[None]).reshape(-1)
    np.testing.assert_allclose(np.asarray(seg2)[idx], np.asarray(seg)[idx])


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("bh,s,dh,blk", [
    (2, 256, 64, 128), (4, 128, 128, 64), (1, 512, 64, 128),
    (2, 200, 64, 64),                       # padded path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(bh, s, dh, blk, dtype):
    q = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, s, dh)), dtype)
    got = flash_attention(q, k, v, block_q=blk, block_k=blk)
    want = attention_ref(q, k, v)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gascore_dma_single_device_identity():
    """n=1 ring degenerates to identity (the multi-device RDMA path runs
    in tests/md_checks.py under 8 host devices)."""
    import jax
    from repro.kernels.gascore_dma.gascore_dma import ring_allreduce_dma_local
    from repro.runtime.topology import make_cpu_mesh
    from jax.sharding import PartitionSpec as P
    mesh = make_cpu_mesh(1, ("x",))
    x = jnp.asarray(RNG.standard_normal(128), jnp.float32)
    out = shard_map(
        lambda v: ring_allreduce_dma_local(v, axis_name="x", n=1),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_flash_is_causal():
    """Changing future keys must not change earlier outputs."""
    bh, s, dh = 1, 256, 64
    q = jnp.asarray(RNG.standard_normal((bh, s, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, s, dh)), jnp.float32)
    out1 = flash_attention(q, k, v)
    k2 = k.at[:, s // 2:].set(RNG.standard_normal((bh, s // 2, dh)))
    v2 = v.at[:, s // 2:].set(RNG.standard_normal((bh, s // 2, dh)))
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out1)[:, :s // 2],
                               np.asarray(out2)[:, :s // 2], rtol=1e-5)
