"""Lossy-transport resilience: CRC property tests, deterministic fault
seeding, the error-bit registry, R5 lint rule, and the graceful-
degradation satellites (frontend deadlines/backoff, checkpoint
checksums).  Multi-device protocol semantics run in a subprocess
(tests/fault_checks.py)."""

import dataclasses

import numpy as np
import pytest

from conftest import run_subprocess_checks


def test_fault_semantics_multidevice():
    out = run_subprocess_checks("fault_checks.py", n_devices=8, timeout=1500)
    assert "FAULT_CHECKS_ALL_PASS" in out


# -- CRC seal ---------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int32", "uint32"])
@pytest.mark.parametrize("nseg", [1, 2, 4])
def test_crc_detects_every_single_bit_flip(dtype, nseg):
    import jax.numpy as jnp

    from repro.core import am

    rng = np.random.default_rng(hash((dtype, nseg)) % (2 ** 31))
    W = 4
    pay = rng.integers(-2 ** 31, 2 ** 31, size=(nseg, W),
                       dtype=np.int64).astype(np.int32)
    pkt = np.zeros((nseg, am.HDR_WORDS + W), np.int32)
    pkt[:, 0] = am.LONG
    pkt[:, am.HDR_WORDS:] = pay.view(np.int32) if dtype == "int32" else pay
    sealed = np.asarray(am.seal_packet(jnp.asarray(pkt)))
    assert bool(np.asarray(am.packet_crc_ok(jnp.asarray(sealed))).all())
    width = sealed.shape[-1]
    for row in range(nseg):
        for bit in range(width * 32):
            corr = sealed.copy()
            u = corr[row].view(np.uint32)
            u[bit // 32] ^= np.uint32(1) << np.uint32(bit % 32)
            ok = np.asarray(am.packet_crc_ok(jnp.asarray(corr)))
            assert not ok[row], (row, bit)
            # other rows untouched -> still sealed
            assert ok.sum() == nseg - 1


def test_crc_nop_row_is_sealed_zero():
    import jax.numpy as jnp

    from repro.core import am

    z = jnp.zeros((3, am.HDR_WORDS + 4), jnp.int32)
    assert int(np.asarray(am.packet_crc(z)).sum()) == 0
    assert bool(np.asarray(am.packet_crc_ok(z)).all())
    # seal is idempotent
    s1 = am.seal_packet(z)
    np.testing.assert_array_equal(np.asarray(s1),
                                  np.asarray(am.seal_packet(s1)))


# -- deterministic fault process -------------------------------------------

def test_fault_draws_deterministic_and_decorrelated():
    import jax
    import jax.numpy as jnp

    from repro.core import am
    from repro.core import faults as flt

    fm = flt.FaultModel(drop=0.3, dup=0.2, corrupt=0.1, seed=42)
    rows = jnp.tile(
        am.seal_packet(jnp.arange(am.HDR_WORDS + 4, dtype=jnp.int32)
                       .at[0].set(am.LONG))[None], (4, 1))
    keyspace = [(r, t, e, rnd, d)
                for r in (0, 3) for t in (1, 2) for e in (1, 2)
                for rnd in (0, 1) for d in (flt.DIR_DATA, flt.DIR_REPLY)]
    outs = {}
    for args in keyspace:
        k = flt.fault_key(fm, *args)
        out, dupm = flt.inject(rows, k, 0.5, 0.5, 0.5)
        outs[args] = (np.asarray(out), np.asarray(dupm))
        # same key -> identical draws (trace-independent reproducibility)
        out2, dupm2 = flt.inject(rows, k, 0.5, 0.5, 0.5)
        np.testing.assert_array_equal(np.asarray(out2), outs[args][0])
        np.testing.assert_array_equal(np.asarray(dupm2), outs[args][1])
    # different (receiver/token/epoch/round/direction) -> not all equal
    distinct = {o[0].tobytes() for o in outs.values()}
    assert len(distinct) > 1


def test_faults_only_touch_live_rows():
    import jax
    import jax.numpy as jnp

    from repro.core import faults as flt

    rows = jnp.zeros((4, 20), jnp.int32)       # all NOP
    k = flt.fault_key(flt.FaultModel(seed=1), 0, 1, 1, 0, flt.DIR_DATA)
    out, dupm = flt.inject(rows, k, 1.0, 1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(out), 0)
    assert not np.asarray(dupm).any()


def test_fault_model_validation():
    from repro.core.faults import FaultModel

    with pytest.raises(ValueError):
        FaultModel(drop=1.5)
    with pytest.raises(ValueError):
        FaultModel(corrupt=-0.1)
    assert FaultModel().lossless
    assert not FaultModel(dup=0.1).lossless


def test_lossy_transport_construction():
    from repro.core.faults import FaultModel
    from repro.runtime import LossyTransport, is_lossy
    from repro.runtime.transport import TCP, LinkClass

    with pytest.raises(ValueError):
        LossyTransport()                        # needs a FaultModel
    with pytest.raises(ValueError):
        LossyTransport(faults=FaultModel(drop=0.1), max_retries=-1)
    t = LossyTransport(faults=FaultModel(drop=0.1, seed=2))
    assert is_lossy(t) and not is_lossy(TCP)
    assert not is_lossy(LossyTransport(faults=FaultModel()))
    assert t.probs_for(0, 1) == (0.1, 0.0, 0.0)
    assert t.probs_for(2, 2) == (0.0, 0.0, 0.0)     # LOCAL stays clean
    # custom link classifier: everything ICI -> lossless
    t2 = dataclasses.replace(t, link_of=lambda s, d: LinkClass.ICI)
    assert t2.probs_for(0, 1) == (0.0, 0.0, 0.0)


# -- error-bit registry -----------------------------------------------------

def test_error_registry_decodes_all_bits():
    import jax.numpy as jnp

    from repro.core import state as st

    s = st.PgasState.make(8)
    assert st.raise_on_error(s) is s
    for bit, exc in ((st.ERR_WAIT_UNDERFLOW, st.WaitUnderflowError),
                     (st.ERR_CRC, st.CrcError),
                     (st.ERR_RETRY_EXHAUSTED, st.RetryExhaustedError)):
        bad = dataclasses.replace(s, error=jnp.asarray(bit, jnp.int32))
        with pytest.raises(exc):
            st.raise_on_error(bad, where="test")
        assert st.raise_on_error(bad, ignore=bit) is bad
    # multiple bits: lowest decodes first
    bad = dataclasses.replace(
        s, error=jnp.asarray(st.ERR_CRC | st.ERR_RETRY_EXHAUSTED, jnp.int32))
    with pytest.raises(st.CrcError):
        st.raise_on_error(bad)
    with pytest.raises(st.RetryExhaustedError):
        st.raise_on_error(bad, ignore=st.ERR_CRC)
    assert st.error_names(st.ERR_CRC | st.ERR_RETRY_EXHAUSTED) == (
        "ERR_CRC", "ERR_RETRY_EXHAUSTED")
    # unregistered bits fail loudly instead of passing silently
    bad = dataclasses.replace(s, error=jnp.asarray(1 << 20, jnp.int32))
    with pytest.raises(st.ShoalError, match="unregistered"):
        st.raise_on_error(bad)


def test_register_error_bit_validation():
    from repro.core import state as st

    with pytest.raises(ValueError):
        st.register_error_bit(3, "NOT_A_POWER")
    with pytest.raises(ValueError):
        st.register_error_bit(st.ERR_CRC, "CLASH")


# -- R5 lint rule -----------------------------------------------------------

def _ev(seq, **kw):
    from repro.analysis.trace import CommEvent

    kw.setdefault("op", "put_long")
    kw.setdefault("pattern", ((0, 1),))
    return CommEvent(seq=seq, **kw)


def test_r5_flags_retransmit_without_dedup():
    from repro.analysis.report import ERROR, WARNING
    from repro.analysis.rules import check_r5

    bad = check_r5([_ev(0, lossy=True, acked=True, retries=4, dedup=False)])
    assert len(bad) == 1 and bad[0].rule == "R5" \
        and bad[0].severity == ERROR
    warn_noretry = check_r5([_ev(0, lossy=True, acked=True, retries=0)])
    assert [f.severity for f in warn_noretry] == [WARNING]
    warn_async = check_r5([_ev(0, lossy=True, acked=False)])
    assert [f.severity for f in warn_async] == [WARNING]
    assert not check_r5([_ev(0, lossy=True, acked=True, retries=4,
                             dedup=True)])
    assert not check_r5([_ev(0, lossy=False, acked=True)])


def test_r3_timeout_wait_not_underflow():
    from repro.analysis.rules import check_r3

    # n=2 waited, only 1 issued: hard wait errors, timeout wait does not
    hard = check_r3([_ev(0, acked=True, token=1),
                     _ev(1, op="wait_replies", token=1, wait_n=2)])
    assert any(f.rule == "R3" for f in hard)
    soft = check_r3([_ev(0, acked=True, token=1),
                     _ev(1, op="wait_replies", token=1, wait_n=2,
                         timeout=True)])
    assert not soft


# -- frontend graceful degradation -----------------------------------------

class _FakeEngine:
    """Minimal ServeEngine surface: `lanes` concurrent jobs, each done
    after `steps_per_job` steps."""

    def __init__(self, lanes=1, steps_per_job=1):
        self.lanes, self.steps_per_job = lanes, steps_per_job
        self.running: dict[int, int] = {}
        self.drained = 0

    def submit(self, req) -> bool:
        if len(self.running) >= self.lanes:
            return False
        self.running[req.rid] = self.steps_per_job
        return True

    def step(self):
        for rid in list(self.running):
            self.running[rid] -= 1
            if self.running[rid] <= 0:
                del self.running[rid]

    def drain(self):
        self.drained += 1

    @property
    def idle(self):
        return not self.running


def test_frontend_deadline_expires_queued_jobs():
    from repro.serving.frontend import ServeFrontend, TIMED_OUT

    fe = ServeFrontend(_FakeEngine(lanes=1, steps_per_job=3), max_queue=8)
    slow = fe.submit([1], 4)                   # occupies the single lane
    late = fe.submit([2], 4, deadline_s=0.0)   # expires before admission
    fe.pump()
    assert fe.status(slow.rid) == "running"
    fe.pump()
    assert fe.status(late.rid) == TIMED_OUT
    assert fe.stats()["expired"] == 1
    with pytest.raises(ValueError, match="timed out"):
        fe.result(late.rid)


def test_frontend_backoff_retry_then_reject():
    import threading

    from repro.serving.frontend import ServeFrontend

    fe = ServeFrontend(_FakeEngine(lanes=1, steps_per_job=1), max_queue=1)
    fe.submit([1], 1)
    # queue full; a concurrent pump drains it during the backoff sleep
    t = threading.Timer(0.02, fe.pump)
    t.start()
    job = fe.submit([2], 1, retries=8, backoff_s=0.01)
    t.join()
    assert job.status != "rejected"
    # no pump: retries exhaust and the job is rejected, queue stays bounded
    fe2 = ServeFrontend(_FakeEngine(), max_queue=1)
    fe2.submit([1], 1)
    job2 = fe2.submit([2], 1, retries=2, backoff_s=0.001)
    assert job2.status == "rejected"
    assert fe2.queue_depth == 1


def test_frontend_stop_raises_on_wedged_runner():
    import threading
    import time as _time

    from repro.serving.frontend import ServeFrontend

    fe = ServeFrontend(_FakeEngine(), max_queue=2)
    release = threading.Event()

    # a pump that blocks until released simulates a wedged engine step
    def wedged_pump():
        release.wait(5.0)
        return False

    fe.pump = wedged_pump
    fe.start(poll_s=0.001)
    _time.sleep(0.01)
    with pytest.raises(RuntimeError, match="failed to stop"):
        fe.stop(timeout=0.05)
    assert fe.engine.drained == 0      # never drained under a live runner
    release.set()
    fe.stop(timeout=5.0)               # second stop succeeds
    assert fe.engine.drained == 1


def test_frontend_stop_clean():
    from repro.serving.frontend import ServeFrontend

    fe = ServeFrontend(_FakeEngine(), max_queue=2)
    fe.start(poll_s=0.001)
    fe.submit([1], 1)
    fe.stop(timeout=5.0)
    assert fe.engine.drained == 1


# -- checkpoint checksum ----------------------------------------------------

def test_checkpoint_checksum_error_names_digests(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, ChecksumError

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(1, tree)
    # verified restore round-trips
    out, _ = mgr.restore(tree, verify=True)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))
    # corrupt the leaf file persistently: re-read retry must still fail
    d = tmp_path / "step_00000001"
    leaf = next(p for p in d.iterdir() if p.suffix == ".npy")
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(ChecksumError) as ei:
        mgr.restore(tree, verify=True)
    e = ei.value
    assert e.path == "w" and e.file == leaf.name
    assert e.expected != e.actual
    assert e.expected in str(e) and e.actual in str(e)
    assert isinstance(e, IOError)
    # unverified restore still reads the (corrupt) bytes — opt-in check
    mgr.restore(tree, verify=False)


def test_checkpoint_checksum_transient_reread(tmp_path, monkeypatch):
    """One torn read recovers: the first hash mismatches, the re-read
    sees good bytes and the restore succeeds."""
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, checkpoint as ckpt_mod

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    mgr.save(2, tree)
    real = ckpt_mod.hashlib.sha256
    calls = {"n": 0}

    class _Flaky:
        def __init__(self, data):
            self._h = real(data)
            calls["n"] += 1
            self._lie = calls["n"] == 1

        def hexdigest(self):
            return "0" * 64 if self._lie else self._h.hexdigest()

    monkeypatch.setattr(ckpt_mod.hashlib, "sha256", _Flaky)
    out, _ = mgr.restore(tree, verify=True)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4))
    assert calls["n"] == 2
