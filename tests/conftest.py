# NOTE: no XLA_FLAGS here — unit tests and smoke tests run on the single
# real CPU device.  Multi-device semantics are exercised by
# tests/md_checks.py in a subprocess with its own device-count flag, and
# the production 512-device mesh only ever exists inside
# repro.launch.dryrun processes.

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess_checks(script: str, n_devices: int = 8, timeout=900):
    """Run a check script in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", script)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def repo_root():
    return REPO


@pytest.fixture(scope="session")
def lint_clean():
    """shoal-lint pytest surface: ``lint_clean(fn, *args)`` traces the
    program, runs rules R1-R4, and raises CommLintError (an
    AssertionError rendering every finding) unless it is clean."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.analysis.jaxpr_lint import lint_clean as _lint_clean

    return _lint_clean
