"""Disaggregated-serving tier tests.

Single-device tests run inline: KV segment layout + validation, a
pure-local migrate round trip, migrated-adoption bit-identity against
the in-place engine oracle, the admission front-end's queue semantics,
and the satellite regressions (engine drain, global_addr range errors,
vectored-put validation, ReplyMailbox traced-token message).  The real
cross-kernel migration — HLO collective budget and the migrated-decode
oracle over disjoint prefill/decode slices — runs in a subprocess via
tests/serving_checks.py with its own host-device count.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_subprocess_checks

from repro.actors.events import EventMailbox, SlotEvent
from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.launch.mesh import ServingSlices
from repro.models.model import ModelConfig, build_model
from repro.runtime import TCP
from repro.runtime.topology import make_cpu_mesh
from repro.serving import (DONE, QUEUED, REJECTED, RUNNING, KvSegmentSpace,
                           MIGRATE_TOKEN, Request, ServeEngine, ServeFrontend)
from repro.serving.disagg import PrefillWorker, _lane_words
from repro.serving.engine import lane_slice

LOCAL = [(0, 0)]

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   dtype=jnp.float32)
SLOTS = 16


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY)
    return model, model.init(jax.random.PRNGKey(0))


def make_gas(segment_words=64, transport=TCP):
    mesh = make_cpu_mesh(1, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=transport,
                       segment_words=segment_words)
    return ctx, GlobalAddressSpace(ctx)


def make_kv(model, lanes=2, slots=SLOTS):
    ctx, gas = make_gas(segment_words=lanes * _lane_words(model, slots))
    return ctx, gas, KvSegmentSpace(gas, model, lanes=lanes, slots=slots)


# -- KvSegmentSpace layout ---------------------------------------------------

def test_kv_space_layout(tiny_model):
    model, _ = tiny_model
    ctx, gas, kv = make_kv(model)
    assert kv.lane_words == _lane_words(model, SLOTS)
    assert kv.lane_base(1) == kv.lane_words
    with pytest.raises(ValueError, match="lane 2 out of range"):
        kv.lane_base(2)
    # one address per (leaf, layer) block, disjoint and in-segment
    addrs = kv.block_addrs(1)
    assert len(addrs) == sum(leaf.layers for leaf in kv.leaves)
    assert all(kv.lane_base(1) <= a < 2 * kv.lane_words for a in addrs)
    assert len(set(addrs)) == len(addrs)
    # layer stride is the per-layer word count of each leaf
    i = 0
    for leaf in kv.leaves:
        for layer in range(leaf.layers):
            assert addrs[i] == kv.lane_base(1) + leaf.offset + layer * leaf.words
            i += 1
    assert "lane_words" in kv.describe()


def test_kv_space_validates_capacity(tiny_model):
    model, _ = tiny_model
    ctx, gas = make_gas(segment_words=64)
    with pytest.raises(ValueError, match="KvSegmentSpace needs"):
        KvSegmentSpace(gas, model, lanes=2, slots=SLOTS)
    tiny_mtu = dataclasses.replace(TCP, max_packet_bytes=64)
    ctx, gas = make_gas(segment_words=1 << 16, transport=tiny_mtu)
    with pytest.raises(ValueError, match="MTU"):
        KvSegmentSpace(gas, model, lanes=1, slots=SLOTS)


def test_kv_pack_rejects_foreign_structure(tiny_model):
    model, _ = tiny_model
    ctx, gas, kv = make_kv(model)
    with pytest.raises(ValueError, match="does not match"):
        kv.pack_lane({"x": jnp.zeros((2, 1, 4))})


def test_kv_pack_unpack_roundtrip_exact(tiny_model):
    """Value-cast through the f32 segment is exact: unpack(pack(cache))
    reproduces every leaf bit-for-bit (incl. the int32 ring positions)."""
    model, params = tiny_model
    ctx, gas, kv = make_kv(model)
    worker = PrefillWorker(model, params, SLOTS, kernel_id=0)
    _, lane_cache = worker.prefill(np.asarray([3, 14, 15, 9], np.int32))
    blocks = kv.pack_lane(lane_cache)
    seg = np.zeros(ctx.segment_words, np.float32)
    for a, b in zip(kv.block_addrs(1), blocks):
        arr = np.asarray(b)
        seg[a:a + arr.size] = arr
    got = kv.unpack_lane(seg, 1)
    for want, have in zip(jax.tree.leaves(lane_cache), jax.tree.leaves(got)):
        assert want.dtype == have.dtype
        np.testing.assert_array_equal(np.asarray(want), np.asarray(have))


def test_kv_migrate_local_pattern(tiny_model):
    """Pure-local migrate (src == dst): blocks land at the lane's block
    addresses, the coalesced reply balances the credit, no error bits."""
    model, params = tiny_model
    ctx, gas, kv = make_kv(model)
    worker = PrefillWorker(model, params, SLOTS, kernel_id=0)
    _, lane_cache = worker.prefill(np.asarray([7, 8, 30], np.int32))
    blocks = tuple(kv.pack_lane(lane_cache))

    def prog(st):
        return kv.migrate(st, blocks, LOCAL, lane=1)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    assert int(np.asarray(out.error)[0]) == 0
    assert int(np.asarray(out.credits)[0][MIGRATE_TOKEN]) == 0
    got = kv.unpack_lane(np.asarray(out.segment)[0], 1)
    for want, have in zip(jax.tree.leaves(lane_cache), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(want), np.asarray(have))


# -- migrated adoption vs in-place oracle ------------------------------------

def test_migrated_adoption_matches_oracle(tiny_model):
    """A request prefetched on a worker, round-tripped through the PGAS
    segment layout and adopted mid-stream decodes to exactly the tokens
    the engine's own submit path produces — with mixed lane progress and
    ragged prompt lengths."""
    model, params = tiny_model
    ctx, gas, kv = make_kv(model)
    worker = PrefillWorker(model, params, SLOTS, kernel_id=0)
    prompts = [[3, 14, 15, 9, 2], [7, 8], [30, 2, 9]]
    max_new = [6, 4, 5]

    def place_adopt(eng, req):
        lane = eng.find_free_lane()
        logits, lane_cache = worker.prefill(req.prompt)
        tok = eng._sample(np.asarray(logits))
        seg = np.zeros(ctx.segment_words, np.float32)
        for a, b in zip(kv.block_addrs(lane), kv.pack_lane(lane_cache)):
            arr = np.asarray(b)
            seg[a:a + arr.size] = arr
        req.out.append(int(tok))
        eng.adopt_lane(lane, kv.unpack_lane(seg, lane), req,
                       pos=len(req.prompt), last_tok=int(tok))

    def drive(place):
        eng = ServeEngine(model, params, lanes=2, slots=SLOTS)
        reqs = [Request(i, np.asarray(p, np.int32), m)
                for i, (p, m) in enumerate(zip(prompts, max_new))]
        place(eng, reqs[0])
        eng.step(), eng.step()
        place(eng, reqs[1])          # lane 1 joins two steps behind lane 0
        while not reqs[2].out:
            if eng.find_free_lane() is not None:
                place(eng, reqs[2])  # reuse whichever lane freed first
            else:
                eng.step()
        while not eng.idle:
            eng.step()
        eng.drain()
        return [r.out for r in reqs]

    oracle = drive(lambda eng, req: eng.submit(req))
    migrated = drive(place_adopt)
    assert migrated == oracle
    assert [len(o) for o in oracle] == max_new


def test_adopt_lane_refuses_busy_lane(tiny_model):
    model, params = tiny_model
    eng = ServeEngine(model, params, lanes=1, slots=SLOTS)
    eng.submit(Request(0, np.asarray([1, 2], np.int32), 4))
    lane_cache = lane_slice(eng.cache, 0)
    with pytest.raises(ValueError, match="busy"):
        eng.adopt_lane(0, lane_cache, Request(1, np.asarray([3], np.int32), 2),
                       pos=1, last_tok=0)


# -- satellite: engine drain --------------------------------------------------

def test_engine_drain_delivers_trailing_events(tiny_model):
    """A stream ending between steps used to strand sub-watermark events
    in the mailbox; drain() must force the final delivery."""
    model, params = tiny_model
    batches = []
    eng = ServeEngine(model, params, lanes=1, slots=SLOTS,
                      event_sink=batches.append, event_watermark=64)
    eng.submit(Request(0, np.asarray([1, 2, 3], np.int32), 2))
    assert batches == []            # acquire is pending, below watermark
    out = eng.drain()
    assert [e.kind for e in out] == ["acquire"]
    assert batches == [out]
    assert eng.events.pending == 0
    assert eng.drain() == []        # idempotent


def test_engine_run_ends_drained(tiny_model):
    model, params = tiny_model
    batches = []
    eng = ServeEngine(model, params, lanes=1, slots=SLOTS,
                      event_sink=batches.append, event_watermark=64)
    eng.run([Request(i, np.asarray([i + 1, i + 2], np.int32), 2)
             for i in range(2)])
    assert eng.events.pending == 0
    kinds = [e.kind for b in batches for e in b]
    assert kinds.count("acquire") == 2 and kinds.count("release") == 2


# -- satellite: address-space range errors ------------------------------------

def test_global_addr_range_errors():
    ctx, gas = make_gas(segment_words=64)
    assert gas.global_addr(0, 63) == 63
    with pytest.raises(ValueError, match="kernel 1 out of range"):
        gas.global_addr(1, 0)
    with pytest.raises(ValueError, match=r"offset 64 outside the 64-word"):
        gas.global_addr(0, 64)
    with pytest.raises(ValueError, match="kernel 0"):
        gas.global_addr(0, -1)


def test_check_local_range_and_vectored_addrs():
    ctx, gas = make_gas(segment_words=64)
    assert gas.check_local_range(0, 60, 4) == 60
    with pytest.raises(ValueError, match="overruns"):
        gas.check_local_range(0, 60, 5)
    assert gas.vectored_addrs(0, 8, [4, 4]) == [8, 12]
    assert gas.vectored_addrs(0, 8, [4, 4], stride=16) == [8, 24]
    with pytest.raises(ValueError, match="overruns"):
        gas.vectored_addrs(0, 50, [4, 8], stride=8)    # 2nd block ends at 66
    with pytest.raises(ValueError, match="outside the"):
        gas.vectored_addrs(0, 56, [4, 4], stride=16)   # 2nd block starts at 72


def test_put_long_vectored_validation():
    ctx, gas = make_gas(segment_words=64)
    st = ctx.make_state()
    blocks = [jnp.ones(2, jnp.float32), jnp.ones(3, jnp.float32)]
    with pytest.raises(ValueError, match="one destination address per block"):
        ops.put_long_vectored(ctx, st, blocks, LOCAL, [4])
    tiny_mtu = dataclasses.replace(TCP, max_packet_bytes=64)   # 16 words
    ctx2, _ = make_gas(segment_words=64, transport=tiny_mtu)
    big = [jnp.ones(8, jnp.float32), jnp.ones(7, jnp.float32)]
    with pytest.raises(ValueError, match="do not segment"):
        ops.put_long_vectored(ctx2, ctx2.make_state(), big, LOCAL, [0, 8])


# -- satellite: ReplyMailbox traced-token message ------------------------------

def test_reply_mailbox_traced_token_names_the_fix():
    ctx, _ = make_gas()
    rmb = ctx.reply_mailbox()

    def probe(t):
        with pytest.raises(ValueError) as ei:
            rmb.note(LOCAL, t)
        msg = str(ei.value)
        assert "static" in msg
        assert "flush" in msg and "reply_via=None" in msg
        return t

    jax.jit(probe)(jnp.asarray(3))
    assert rmb.pending == 0         # the failed note recorded nothing


# -- serving slices (pure topology logic) --------------------------------------

def test_serving_slices():
    s = ServingSlices(n_prefill=2, n_decode=3)
    assert s.num_kernels == 5
    assert s.prefill_ids == (0, 1) and s.decode_ids == (2, 3, 4)
    assert s.role_of(1) == "prefill" and s.role_of(4) == "decode"
    assert s.migration_pattern(0, 3) == [(0, 3)]
    with pytest.raises(ValueError, match="not in the prefill"):
        s.migration_pattern(3, 2)
    with pytest.raises(ValueError, match="not in the decode"):
        s.migration_pattern(0, 1)
    with pytest.raises(ValueError, match="outside"):
        s.role_of(5)
    with pytest.raises(ValueError, match=">= 1"):
        ServingSlices(n_prefill=0, n_decode=1)


# -- admission front-end -------------------------------------------------------

class FakeEngine:
    """Pure-python ServeEngine stand-in: same scheduler surface, same
    EventMailbox accounting, no XLA."""

    def __init__(self, lanes=2, steps=3):
        self.events = EventMailbox(watermark=1000)
        self.active = [None] * lanes
        self._left = [0] * lanes
        self.steps = steps

    def find_free_lane(self):
        for lane, cur in enumerate(self.active):
            if cur is None:
                return lane
        return None

    def submit(self, req):
        lane = self.find_free_lane()
        if lane is None:
            return False
        self.active[lane] = req
        self._left[lane] = self.steps
        req.out.append(1)
        self.events.send(SlotEvent("acquire", lane, req.rid))
        return True

    def step(self):
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(0)
            self._left[lane] -= 1
            if self._left[lane] <= 0:
                req.done = True
                self.active[lane] = None
                self.events.send(SlotEvent("release", lane, req.rid))
        self.events.flush()

    @property
    def idle(self):
        return all(r is None for r in self.active)

    def drain(self):
        return self.events.flush()


def test_frontend_backpressure_rejects_beyond_bound():
    fe = ServeFrontend(FakeEngine(lanes=1, steps=2), max_queue=2)
    jobs = [fe.submit([1, 2], max_new=3) for _ in range(5)]
    assert [j.status for j in jobs] == [QUEUED, QUEUED,
                                        REJECTED, REJECTED, REJECTED]
    assert fe.queue_depth == 2 and fe.peak_queue_depth == 2
    fe.run_until_idle()
    assert [j.status for j in jobs[:2]] == [DONE, DONE]
    assert fe.peak_queue_depth <= fe.max_queue
    with pytest.raises(ValueError, match="rejected"):
        fe.result(jobs[2].rid)
    stats = fe.stats()
    assert stats["admitted"] == 2 and stats["rejected"] == 3
    assert stats["completed"] == 2 and stats["busy_lanes"] == 0


def test_frontend_status_flow_is_event_driven():
    fe = ServeFrontend(FakeEngine(lanes=1, steps=2), max_queue=4)
    job = fe.submit([5], max_new=3)
    assert fe.status(job.rid) == QUEUED
    assert fe.result(job.rid) is None
    fe.pump()
    assert fe.status(job.rid) == RUNNING
    assert fe.stats()["busy_lanes"] == 1      # acquire event landed
    while fe.pump():
        pass
    assert fe.status(job.rid) == DONE         # release event marked it
    assert fe.result(job.rid) == job.request.out
    with pytest.raises(KeyError):
        fe.status(999)


def test_frontend_runner_thread():
    fe = ServeFrontend(FakeEngine(lanes=2, steps=2), max_queue=8)
    fe.start(poll_s=0.0005)
    try:
        jobs = [fe.submit([i], max_new=3) for i in range(6)]
        deadline = time.monotonic() + 10
        while (any(j.status != DONE for j in jobs)
               and time.monotonic() < deadline):
            time.sleep(0.002)
    finally:
        fe.stop()
    assert all(j.status == DONE for j in jobs)
    with pytest.raises(RuntimeError, match="already started"):
        fe.start(), fe.start()
    fe.stop()


def test_frontend_over_real_engine(tiny_model):
    model, params = tiny_model
    eng = ServeEngine(model, params, lanes=1, slots=SLOTS)
    fe = ServeFrontend(eng, max_queue=4)
    jobs = [fe.submit([i + 1, i + 2], max_new=3) for i in range(3)]
    fe.run_until_idle()
    assert all(j.status == DONE for j in jobs)
    assert all(len(fe.result(j.rid)) == 3 for j in jobs)
    assert eng.events.pending == 0


# -- multi-kernel semantics (subprocess with its own device count) -------------

def test_serving_subprocess_checks():
    out = run_subprocess_checks("serving_checks.py", n_devices=4)
    assert "SERVING_CHECKS_OK" in out
