"""MoE dispatch oracle + serving-engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEDims, _route, init_moe, moe_ffn
from repro.models.model import ModelConfig, build_model
from repro.serving.engine import Request, ServeEngine

RNG = np.random.default_rng(0)


def _moe_oracle(p, x, dims):
    """Per-token loop: each token's top-k experts, gates renormalized —
    the semantics sort-based dispatch must reproduce (unlimited capacity)."""
    T, d = x.shape
    logits = np.asarray(x @ p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros((T, d), np.float32)
    xn = np.asarray(x, np.float32)
    wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("wg", "wu", "wd"))
    for t in range(T):
        top = np.argsort(-probs[t])[:dims.top_k]
        g = probs[t][top]
        g = g / g.sum()
        for gi, e in zip(g, top):
            h = xn[t] @ wg[e]
            h = h / (1 + np.exp(-h)) * (xn[t] @ wu[e])
            out[t] += gi * (h @ wd[e])
    return out


def test_moe_dispatch_matches_oracle():
    dims = MoEDims(n_experts=8, top_k=2, d_ff_expert=32,
                   capacity_factor=8.0)   # no drops
    d = 16
    p = init_moe(jax.random.PRNGKey(0), d, dims)
    x = jnp.asarray(RNG.standard_normal((1, 24, d)), jnp.float32)
    got, aux = moe_ffn(p, x, dims)
    want = _moe_oracle(p, x[0], dims)
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity 1 token per expert, most tokens are dropped: output
    norm shrinks but stays finite (standard capacity semantics)."""
    dims = MoEDims(n_experts=4, top_k=1, d_ff_expert=16,
                   capacity_factor=0.01)
    d = 8
    p = init_moe(jax.random.PRNGKey(1), d, dims)
    x = jnp.asarray(RNG.standard_normal((1, 64, d)), jnp.float32)
    got, _ = moe_ffn(p, x, dims)
    assert np.isfinite(np.asarray(got)).all()
    dims_full = MoEDims(n_experts=4, top_k=1, d_ff_expert=16,
                        capacity_factor=16.0)
    full, _ = moe_ffn(p, x, dims_full)
    assert float(jnp.sum(jnp.abs(got))) < float(jnp.sum(jnp.abs(full)))


def test_router_topk_normalized():
    dims = MoEDims(n_experts=8, top_k=3, d_ff_expert=8)
    w = jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)
    gates, experts, aux = _route(w, jnp.asarray(
        RNG.standard_normal((5, 8)), jnp.float32), dims)
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
    assert experts.shape == (5, 3)
    assert (np.asarray(experts) < 8).all()


# -- serving engine ---------------------------------------------------------------

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   dtype=jnp.float32)


def _greedy_reference(model, params, prompt, max_new):
    """Teacher-forced greedy reference using full forward passes."""
    toks = list(prompt)
    out = []
    for _ in range(max_new):
        logits, _ = model.forward_train(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_greedy_reference():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [3, 14, 15, 9, 2]
    ref = _greedy_reference(model, params, prompt, 6)
    eng = ServeEngine(model, params, lanes=2, slots=32)
    req = Request(rid=0, prompt=np.asarray(prompt, np.int32), max_new=6)
    done = eng.run([req])
    assert done[0].out == ref


def test_engine_batching_invariance():
    """Co-batched requests do not perturb each other's outputs."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    p1, p2 = [5, 6, 7], [30, 2, 9, 11]
    solo = ServeEngine(model, params, lanes=1, slots=32)
    r1 = Request(0, np.asarray(p1, np.int32), 5)
    solo.run([r1])
    duo = ServeEngine(model, params, lanes=2, slots=32)
    r1b = Request(1, np.asarray(p1, np.int32), 5)
    r2b = Request(2, np.asarray(p2, np.int32), 5)
    duo.run([r1b, r2b])
    assert r1b.out == r1.out


def test_engine_lane_reuse():
    """A lane reused by a later request must not leak earlier KV."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [8, 9, 10]
    eng = ServeEngine(model, params, lanes=1, slots=32)
    first = Request(0, np.asarray([40, 41, 42, 43, 44], np.int32), 4)
    eng.run([first])
    second = Request(1, np.asarray(prompt, np.int32), 4)
    eng.run([second])
    fresh = ServeEngine(model, params, lanes=1, slots=32)
    ref = Request(2, np.asarray(prompt, np.int32), 4)
    fresh.run([ref])
    assert second.out == ref.out


def test_engine_more_requests_than_lanes():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(i, np.asarray([i + 1, i + 2], np.int32), 3)
            for i in range(5)]
    eng = ServeEngine(model, params, lanes=2, slots=16)
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)
