"""Multi-device Shoal semantics, trainer backend agreement, and elastic
restart — run in a subprocess with 8 host devices (the main pytest
process keeps the single real CPU device; see conftest)."""

from conftest import run_subprocess_checks


def test_multidevice_semantics():
    out = run_subprocess_checks("md_checks.py", n_devices=8, timeout=1500)
    assert "MD_CHECKS_ALL_PASS" in out
