"""Data pipeline determinism + optimizer correctness."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline, write_synthetic_corpus
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.dist import (compress_int8, decompress_int8,
                              ef_compress_tree, ef_decompress_tree,
                              make_error_feedback)
from repro.optim.schedule import warmup_cosine


# -- data ----------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab=1000, batch=4, seq=32, seed=7)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1 = p1.batch_at(5)
    b2 = p2.batch_at(5)   # fresh pipeline, same step -> same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(b1["tokens"], p1.batch_at(6)["tokens"])


def test_pipeline_row_slicing_matches_full():
    """Per-host row generation equals the corresponding full-batch rows
    (what makes sharded generation well-defined at scale)."""
    cfg = DataConfig(vocab=500, batch=8, seq=16, seed=3)
    p = TokenPipeline(cfg)
    full = p.rows(11)
    part = p.rows(11, lo=2, hi=5)
    np.testing.assert_array_equal(full[2:5], part)


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=100, batch=2, seq=8, seed=0)
    b = TokenPipeline(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_pipeline_corpus_file():
    with tempfile.TemporaryDirectory() as d:
        path = write_synthetic_corpus(os.path.join(d, "c.bin"), 4096, 128)
        cfg = DataConfig(vocab=128, batch=2, seq=16, seed=0, corpus=path)
        b = TokenPipeline(cfg).batch_at(0)
        assert (b["tokens"] < 128).all()
        b2 = TokenPipeline(cfg).batch_at(0)
        np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_pipeline_modalities():
    cfg = DataConfig(vocab=64, batch=2, seq=8, seed=0, kind="embeddings",
                     d_model=16, image_tokens=4)
    b = TokenPipeline(cfg).batch_at(0)
    assert b["embeddings"].shape == (2, 8, 16)
    assert b["image_feats"].shape == (2, 4, 16)


# -- optimizer -------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.asarray([1.0, 2.0])))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=1e-2)


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    big = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(cfg, big, opt, params)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_weight_decay_only_matrices():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(cfg, zeros, opt, params)
    assert float(jnp.max(jnp.abs(new["b"] - 1.0))) < 1e-6   # bias undecayed
    assert float(jnp.max(new["w"])) < 1.0                   # matrix decayed


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(5)) == pytest.approx(5e-4, rel=1e-3)


# -- compression -----------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(s) / 2 + 1e-6   # half-ULP of the quant grid


def test_error_feedback_accumulates_residual():
    """With EF, the accumulated transmitted signal tracks the true sum of
    gradients; without it, bias persists."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(64) * 1e-4, jnp.float32)  # tiny grads
    res = make_error_feedback({"g": g})["g"]
    sent_total = jnp.zeros_like(g)
    residual = {"g": res}
    for _ in range(50):
        qtree, residual = ef_compress_tree({"g": g}, residual)
        sent = ef_decompress_tree(qtree)["g"]
        sent_total = sent_total + sent
    # over 50 steps the mean transmitted approaches the true gradient
    np.testing.assert_allclose(np.asarray(sent_total / 50), np.asarray(g),
                               atol=float(jnp.max(jnp.abs(g))) * 0.2)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
