"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward + one train step + a decode step on CPU, with
shape and finiteness assertions (the FULL configs are exercised only by
the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.train import Trainer, TrainerConfig

ARCHS = [a.replace("_", "-") for a in configs.ARCH_IDS]


def make_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {"labels": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "embeddings":
        batch["embeddings"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.05, jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        batch["image_feats"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_image_tokens, cfg.d_model)) * 0.05,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = configs.reduced(arch)
    model = build_model(cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)

    logits, aux = jax.jit(model.forward_train)(model.init(jax.random.PRNGKey(0)), batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN/Inf logits"

    trainer = Trainer(model, AdamWConfig(lr=1e-3), TrainerConfig(donate=False))
    state = trainer.init_state(jax.random.PRNGKey(1))
    step = trainer.make_train_step()
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually changed
    delta = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), state.params,
        state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = configs.reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = make_batch(cfg, B, S)
    batch.pop("labels")
    cache = model.make_cache(B, slots=32)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    tok = (jnp.zeros((B, 1), jnp.int32) if cfg.frontend == "tokens"
           else jnp.ones((B, 1, cfg.d_model), jnp.float32) * 0.05)
    imf = batch.get("image_feats")
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, tok, jnp.full((B,), S, jnp.int32), imf)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = configs.full(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == V, arch
    # family-specific details
    ds = configs.full("deepseek-v2-236b")
    assert ds.mla.kv_lora == 512 and ds.moe.n_experts == 160
    assert ds.moe.top_k == 6 and ds.moe.n_shared == 2
    dbrx = configs.full("dbrx-132b")
    assert dbrx.moe.n_experts == 16 and dbrx.moe.top_k == 4
    rg = configs.full("recurrentgemma-2b")
    assert rg.window == 2048 and rg.sub_quadratic
    xl = configs.full("xlstm-350m")
    assert xl.slstm_every == 8 and xl.sub_quadratic
    q = configs.full("qwen2-1.5b")
    assert q.qkv_bias and q.tie_embeddings


def test_long_500k_applicability():
    from repro.configs.shapes import applicable
    for arch in ARCHS:
        cfg = configs.full(arch)
        expect = arch in ("recurrentgemma-2b", "xlstm-350m")
        assert applicable(cfg, "long_500k") == expect, arch
        assert applicable(cfg, "train_4k")


def test_prefill_decode_consistency():
    """Decoding token-by-token must reproduce the teacher-forced forward
    logits — the strongest cache-correctness check."""
    cfg = configs.reduced("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    fwd_logits, _ = jax.jit(model.forward_train)(params, {"tokens": toks})

    cache = model.make_cache(B, slots=32)
    # prefill the first 4 tokens, then decode the rest one at a time
    p = 4
    lg, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :p]}, cache)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(fwd_logits[:, p - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    dec = jax.jit(model.decode_step)
    for t in range(p, S):
        lg, cache = dec(params, cache, toks[:, t:t + 1],
                        jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(fwd_logits[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_prefill_decode_consistency_hybrid():
    """Same for recurrentgemma (RG-LRU state + windowed ring cache)."""
    cfg = configs.reduced("recurrentgemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    B, S = 1, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    fwd_logits, _ = jax.jit(model.forward_train)(params, {"tokens": toks})
    cache = model.make_cache(B, slots=cfg.window)
    lg, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :3]}, cache)
    dec = jax.jit(model.decode_step)
    for t in range(3, S):
        lg, cache = dec(params, cache, toks[:, t:t + 1],
                        jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(fwd_logits[:, t], np.float32),
                                   rtol=5e-3, atol=5e-3)


def test_prefill_decode_consistency_xlstm():
    cfg = configs.reduced("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    B, S = 1, 9
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    fwd_logits, _ = jax.jit(model.forward_train)(params, {"tokens": toks})
    cache = model.make_cache(B, slots=16)
    lg, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :3]}, cache)
    dec = jax.jit(model.decode_step)
    for t in range(3, S):
        lg, cache = dec(params, cache, toks[:, t:t + 1],
                        jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(fwd_logits[:, t], np.float32),
                                   rtol=5e-3, atol=5e-3)
