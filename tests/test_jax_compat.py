"""Compat-path tests for the old-jax shard_map shim (satellite of the
actor-layer PR): partial-manual numerics, the auto-axis spec guard, and
manual-axis introspection all run in a subprocess with 8 host devices
(tests/compat_checks.py) so both mesh axes have real extent."""

from conftest import run_subprocess_checks


def test_compat_checks_multidevice():
    out = run_subprocess_checks("compat_checks.py")
    assert "COMPAT_CHECKS_ALL_PASS" in out
