"""Compat-path checks for runtime.jax_compat.shard_map.

Run by tests/test_jax_compat.py in a subprocess with 8 host devices.
The old-jax (< 0.6) shim replaces partial-manual shard_map with a FULLY
manual region; this is sound only while the auto (non-manual) axes stay
unnamed in the specs (they replicate — different cost, same values).
These checks pin both halves of that contract:

* partial-manual numerics agree with the direct computation on whatever
  jax is installed (replication path on old jax, true partial-manual on
  new jax);
* a spec that *shards over* an auto axis of size > 1 raises a clear
  NotImplementedError on old jax instead of silently replicating (which
  would change per-shard shapes and semantics inside the body);
* size-1 auto axes may appear in specs (sharding over them is a no-op).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import bound_axis_names, make_mesh, shard_map

OLD_JAX = not hasattr(jax, "shard_map")


def check(name, ok, detail=""):
    assert ok, f"{name}: FAILED {detail}"
    print(f"[compat] {name} ok {detail}")


def test_partial_manual_numerics():
    """data axis manual, model axis auto-but-unnamed: the psum over the
    manual axis must produce the exact global sum on both jax paths."""
    mesh = make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(2 * 3, dtype=jnp.float32).reshape(2, 3)

    def body(xs):
        return jax.lax.psum(xs, "data")

    fn = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(),
                   axis_names={"data"}, check_vma=False)
    out = jax.jit(fn)(x)
    # per-device block is (1, 3); psum over "data" -> the global column
    # sum, replicated (out_specs=P() keeps the block shape)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x).sum(0, keepdims=True))
    check("partial-manual numerics", True, f"(old_jax={OLD_JAX})")


def test_model_axis_spec_guard():
    """Naming a size>1 auto axis in a spec must raise on old jax (the
    shim cannot honor it) rather than silently replicate."""
    if not OLD_JAX:
        print("[compat] model-axis spec guard skipped (new jax: true "
              "partial-manual mode handles it)")
        return
    mesh = make_mesh((2, 4), ("data", "model"))

    def body(xs):
        return xs

    try:
        shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                  out_specs=P("data", "model"), axis_names={"data"},
                  check_vma=False)
    except NotImplementedError as e:
        assert "model" in str(e) and "fully-manual" in str(e), e
        check("model-axis spec guard", True, "(raises NotImplementedError)")
        return
    raise AssertionError(
        "old-jax shim accepted a spec sharding over auto axis 'model'")


def test_size1_auto_axis_allowed():
    """A size-1 auto axis named in a spec is a no-op and must not raise
    (replication over size 1 IS sharding over size 1)."""
    mesh = make_mesh((8, 1), ("data", "model"))
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

    def body(xs):
        return xs * 2

    fn = shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                   out_specs=P("data", "model"), axis_names={"data"},
                   check_vma=False)
    out = jax.jit(fn)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2)
    check("size-1 auto axis allowed", True)


def test_bound_axis_names_introspection():
    """On old jax, bound_axis_names() inside the (fully manual) region
    reports the manual axes — the hook model.py uses to skip sharding
    constraints that mention them; empty on new jax."""
    mesh = make_mesh((2, 4), ("data", "model"))
    seen = []

    def body(xs):
        seen.append(bound_axis_names())
        return xs

    fn = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   axis_names={"data"}, check_vma=False)
    jax.jit(fn)(jnp.zeros((2, 3), jnp.float32))
    if OLD_JAX:
        assert "data" in seen[0], seen
    else:
        assert seen[0] == frozenset(), seen
    check("bound_axis_names introspection", True, f"({sorted(seen[0])})")


def main():
    test_partial_manual_numerics()
    test_model_axis_spec_guard()
    test_size1_auto_axis_allowed()
    test_bound_axis_names_introspection()
    print("COMPAT_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
