"""Multi-device lossy-transport semantics: the reliability protocol end
to end on 8 host devices.  Run by tests/test_faults.py in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.faults import FaultModel
from repro.core.state import (ERR_CRC, ERR_RETRY_EXHAUSTED, CrcError,
                              RetryExhaustedError, ShoalContext,
                              raise_on_error)
from repro.runtime import TCP, LossyTransport, make_cpu_mesh
from repro.training.elastic import delivery_live_mask

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]
MTU = 16                # 4 payload words per packet -> 16-word put = 4 segs
PAY = 16


def check(name):
    print(f"[faults] {name}", flush=True)


def build(transport, *, dedup=True, wait_timeout=True):
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(PAY, dtype=jnp.float32) + 1) * (me + 1)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1,
                          dedup=dedup)
        return ops.wait_replies(ctx, st, token=1, n=1, timeout=wait_timeout)

    return jax.jit(gas.spmd(prog)), gas


def oracle_segment():
    tcp_small = TCP.__class__(name="tcp", acked=True, max_packet_bytes=MTU)
    fn, gas = build(tcp_small, wait_timeout=False)
    return np.asarray(fn(gas.make_global_state()).segment)


ORACLE = oracle_segment()


def test_reliable_put_delivers_under_loss():
    check("1%-drop acked 4-seg put: bit-identical, ledger drained, retried")
    seen_retry = False
    for seed in (7, 11, 19, 23):
        t = LossyTransport(faults=FaultModel(drop=0.01, seed=seed),
                           max_packet_bytes=MTU)
        fn, gas = build(t)
        st = fn(gas.make_global_state())
        np.testing.assert_array_equal(np.asarray(st.segment), ORACLE)
        assert (np.asarray(st.dedup_seen) == 0).all(), "ledger must drain"
        assert (np.asarray(st.dedup_epoch)[:, 1] == 1).all()
        assert (np.asarray(st.credits) == 0).all()
        assert not (np.asarray(st.error) & ERR_RETRY_EXHAUSTED).any()
        seen_retry |= bool((np.asarray(st.retransmits) > 0).any())
    assert seen_retry, "no seed exercised a retransmit at 1% drop"


def test_corruption_detected_and_recovered():
    check("bit-corruption: ERR_CRC latched, retransmit still delivers")
    t = LossyTransport(faults=FaultModel(drop=0.05, dup=0.02, corrupt=0.02,
                                         seed=3),
                       max_packet_bytes=MTU)
    fn, gas = build(t)
    st = fn(gas.make_global_state())
    np.testing.assert_array_equal(np.asarray(st.segment), ORACLE)
    assert (np.asarray(st.dedup_seen) == 0).all()
    err = np.asarray(st.error)
    assert (err & ERR_CRC).any(), "this seed corrupts at least one packet"
    # raise_on_error decodes the bit to the named exception...
    try:
        raise_on_error(st, where="fault_checks")
    except CrcError as e:
        assert "ERR_CRC" in str(e)
    else:
        raise AssertionError("expected CrcError")
    # ...and ignore= masks expected fault noise
    raise_on_error(st, where="fault_checks", ignore=ERR_CRC)


def test_duplicates_are_idempotent():
    check("dup-heavy link: dedup ledger makes redelivery idempotent")
    t = LossyTransport(faults=FaultModel(dup=0.5, seed=5),
                       max_packet_bytes=MTU)
    fn, gas = build(t)
    st = fn(gas.make_global_state())
    np.testing.assert_array_equal(np.asarray(st.segment), ORACLE)
    assert (np.asarray(st.dedup_seen) == 0).all()
    assert (np.asarray(st.error) == 0).all()


def test_dedup_off_double_applies():
    check("dedup=False + H_ADD: duplicates corrupt the accumulate")
    ctx_t = LossyTransport(faults=FaultModel(dup=0.5, seed=5),
                           max_packet_bytes=MTU)
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=ctx_t, segment_words=64)
    gas = GlobalAddressSpace(ctx)
    from repro.core import handlers as hd

    def prog(st, dedup):
        pay = jnp.ones((PAY,), jnp.float32)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1,
                          handler=hd.H_ADD, dedup=dedup)
        return ops.wait_replies(ctx, st, token=1, n=1, timeout=True)

    st_on = jax.jit(gas.spmd(lambda s: prog(s, True)))(
        gas.make_global_state())
    st_off = jax.jit(gas.spmd(lambda s: prog(s, False)))(
        gas.make_global_state())
    on = np.asarray(st_on.segment)[:, 10:10 + PAY]
    off = np.asarray(st_off.segment)[:, 10:10 + PAY]
    np.testing.assert_array_equal(on, 1.0)       # each word added once
    assert (off > 1.0).any(), \
        "without dedup a duplicated segment must double-apply H_ADD"


def test_exhaustion_latches_and_elastic_drops():
    check("100% drop: ERR_RETRY_EXHAUSTED -> quorum mask drops ranks")
    t = LossyTransport(faults=FaultModel(drop=1.0, seed=0),
                       max_packet_bytes=MTU)
    fn, gas = build(t)
    st = fn(gas.make_global_state())
    err = np.asarray(st.error)
    assert (err & ERR_RETRY_EXHAUSTED).all(), "every sender must exhaust"
    # destination unchanged, no credit ever granted
    assert (np.asarray(st.segment)[:, 10:10 + PAY] == 0).all()
    try:
        raise_on_error(st, where="fault_checks")
    except RetryExhaustedError:
        pass
    else:
        raise AssertionError("expected RetryExhaustedError")
    live = delivery_live_mask(jnp.ones((N,), jnp.float32),
                              jnp.asarray(err))
    assert (np.asarray(live) == 0).all()
    # a clean rank stays live
    live1 = delivery_live_mask(jnp.asarray(1.0), jnp.asarray(0))
    assert float(live1) == 1.0


def test_wait_timeout_drains_partially():
    check("wait_replies timeout=True: partial drain, no underflow latch")
    t = LossyTransport(faults=FaultModel(drop=1.0, seed=0),
                       max_packet_bytes=MTU)
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=t, segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(PAY, dtype=jnp.float32) + 1) * (me + 1)
        st = ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1)
        # every put exhausted -> zero credits; a timeout wait takes what
        # is there (nothing) instead of latching ERR_WAIT_UNDERFLOW
        return ops.wait_replies(ctx, st, token=1, n=1, timeout=True)

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    assert (np.asarray(st.credits) == 0).all()
    err = np.asarray(st.error)
    from repro.core.state import ERR_WAIT_UNDERFLOW
    assert not (err & ERR_WAIT_UNDERFLOW).any()
    assert (err & ERR_RETRY_EXHAUSTED).all()


def test_async_lossy_fire_and_forget():
    check("async put on lossy link: one attempt, losses are losses")
    t = LossyTransport(faults=FaultModel(drop=0.3, seed=9), acked=False,
                       max_packet_bytes=MTU)
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=t, segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(PAY, dtype=jnp.float32) + 1) * (me + 1)
        return ops.put_long(ctx, st, pay, RING, dst_addr=10, token=1,
                            asynchronous=True)

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(st.segment)[:, 10:10 + PAY]
    assert (seg != ORACLE[:, 10:10 + PAY]).any(), \
        "30% drop must lose something (no retransmit on async)"
    assert (np.asarray(st.retransmits) == 0).all()
    assert not (np.asarray(st.error) & ERR_RETRY_EXHAUSTED).any()


def test_unprotected_ops_refuse_lossy():
    check("ops without a protocol refuse lossy transports at trace time")
    t = LossyTransport(faults=FaultModel(drop=0.01, seed=1),
                       max_packet_bytes=MTU)
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=t, segment_words=64)
    gas = GlobalAddressSpace(ctx)
    for fn in (
        lambda st: ops.put_short(ctx, st, RING),
        lambda st: ops.get_long(ctx, st, RING, src_addr=0, nwords=4,
                                dst_addr=8, token=2),
    ):
        try:
            jax.jit(gas.spmd(fn))(gas.make_global_state())
        except NotImplementedError as e:
            assert "lossy" in str(e)
        else:
            raise AssertionError("expected NotImplementedError")


def test_determinism_across_traces():
    check("same seed, two fresh traces: identical faulted outcome")
    t = LossyTransport(faults=FaultModel(drop=0.05, dup=0.05, corrupt=0.05,
                                         seed=13),
                       max_packet_bytes=MTU)
    outs = []
    for _ in range(2):
        fn, gas = build(t)
        st = fn(gas.make_global_state())
        outs.append((np.asarray(st.segment).copy(),
                     np.asarray(st.retransmits).copy(),
                     np.asarray(st.error).copy(),
                     np.asarray(st.tx_words).copy()))
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(a, b)
    # a different seed gives a different fault history (retransmit or
    # tx pattern differs for at least one of these seeds)
    t2 = LossyTransport(faults=FaultModel(drop=0.05, dup=0.05, corrupt=0.05,
                                          seed=14),
                        max_packet_bytes=MTU)
    fn2, gas2 = build(t2)
    st2 = fn2(gas2.make_global_state())
    assert not (np.array_equal(np.asarray(st2.tx_words), outs[0][3])
                and np.array_equal(np.asarray(st2.error), outs[0][2])
                and np.array_equal(np.asarray(st2.retransmits), outs[0][1]))


def main():
    test_reliable_put_delivers_under_loss()
    test_corruption_detected_and_recovered()
    test_duplicates_are_idempotent()
    test_dedup_off_double_applies()
    test_exhaustion_latches_and_elastic_drops()
    test_wait_timeout_drains_partially()
    test_async_lossy_fire_and_forget()
    test_unprotected_ops_refuse_lossy()
    test_determinism_across_traces()
    print("FAULT_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
