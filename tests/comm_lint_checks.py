"""shoal-lint behavioural checks (pass 1 + registry + host debug path).

Run by tests/test_comm_lint.py in a subprocess with 8 host devices.
Exercises every rule against small programs built from the real op
layer — including the PR 6 overlapping-strided-put race on its pre-fix
(unordered vectorized ingress) path, which the analyzer must flag — and
asserts all shipped registry entry points lint clean.
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro import analysis
from repro.analysis import jaxpr_lint, registry
from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext, WaitUnderflowError, raise_on_error
from repro.runtime import TCP, UDP
from repro.runtime.topology import make_cpu_mesh

N = 8
RING = [(i, (i + 1) % N) for i in range(N)]
TINY_TCP = dataclasses.replace(TCP, max_packet_bytes=64)


def make(transport=TCP, segment_words=128):
    ctx = ShoalContext(mesh=make_cpu_mesh(N, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=segment_words)
    return ctx, GlobalAddressSpace(ctx)


def lint(gas, prog, name):
    return jaxpr_lint.lint(gas.spmd(prog), gas.make_global_state(),
                           name=name)


def check(name, ok, detail=""):
    assert ok, f"{name} FAILED {detail}"
    print(f"[comm-lint] {name} ok {detail}")


def rules_of(rep, severity=None):
    return [f.rule for f in rep.findings
            if not f.waived and (severity is None or f.severity == severity)]


# --------------------------------------------------------------------------
# R1: the PR 6 strided race class (regression) + unordered write pairs
# --------------------------------------------------------------------------

def test_r1_strided_prefix_race():
    """overlap=False forces the pre-fix vectorized ingress on aliasing
    blocks — the exact race PR 6 fixed.  The analyzer must flag it."""
    ctx, gas = make()
    pay = jnp.arange(16, dtype=jnp.float32)

    def racy(st):
        st = ops.put_long_strided(ctx, st, pay, RING, dst_addr=0, stride=2,
                                  blk_words=4, nblocks=4, overlap=False,
                                  token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    rep = lint(gas, racy, "strided-prefix-race")
    check("R1 strided pre-fix race flagged", rules_of(rep) == ["R1"],
          f"(findings: {[f.render() for f in rep.findings]})")

    def fixed(st):
        st = ops.put_long_strided(ctx, st, pay, RING, dst_addr=0, stride=2,
                                  blk_words=4, nblocks=4, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    rep = lint(gas, fixed, "strided-ordered")
    check("R1 ordered strided ingress clean", rep.ok,
          f"(findings: {[f.render() for f in rep.findings]})")


def test_r1_unordered_write_pair():
    ctx, gas = make()
    pay = jnp.arange(8, dtype=jnp.float32)

    def racy(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=4, token=1)
        st = ops.put_long(ctx, st, pay + 1, RING, dst_addr=8, token=2)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        return ops.wait_replies(ctx, st, token=2, n=1)

    rep = lint(gas, racy, "overlap-pair")
    check("R1 unordered overlapping puts flagged", "R1" in rules_of(rep))

    def ordered(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=4, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        st = ops.put_long(ctx, st, pay + 1, RING, dst_addr=8, token=2)
        return ops.wait_replies(ctx, st, token=2, n=1)

    rep = lint(gas, ordered, "overlap-pair-waited")
    check("R1 wait-ordered overlapping puts clean", rep.ok,
          f"(findings: {[f.render() for f in rep.findings]})")

    def disjoint(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=0, token=1)
        st = ops.put_long(ctx, st, pay + 1, RING, dst_addr=16, token=2)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        return ops.wait_replies(ctx, st, token=2, n=1)

    rep = lint(gas, disjoint, "disjoint-pair")
    check("R1 disjoint puts clean", rep.ok)


# --------------------------------------------------------------------------
# R2: get of a range with an in-flight put
# --------------------------------------------------------------------------

def test_r2_get_vs_inflight_put():
    ctx, gas = make()
    pay = jnp.arange(8, dtype=jnp.float32)

    def racy(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=4, token=1)
        st, _ = ops.get_medium(ctx, st, RING, src_addr=6, nwords=4, token=2)
        st = ops.wait_replies(ctx, st, token=2, n=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    rep = lint(gas, racy, "get-inflight")
    check("R2 get with in-flight put flagged", "R2" in rules_of(rep))

    def safe(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=4, token=1)
        st = ops.wait_replies(ctx, st, token=1, n=1)
        st, _ = ops.get_medium(ctx, st, RING, src_addr=6, nwords=4, token=2)
        return ops.wait_replies(ctx, st, token=2, n=1)

    rep = lint(gas, safe, "get-after-wait")
    check("R2 get after wait clean", rep.ok,
          f"(findings: {[f.render() for f in rep.findings]})")


# --------------------------------------------------------------------------
# R3: credit flow — underflow, leak, double-spend
# --------------------------------------------------------------------------

def test_r3_credit_flow():
    ctx, gas = make()
    pay = jnp.arange(4, dtype=jnp.float32)

    def underflow(st):
        st = ops.put_long(ctx, st, pay, RING, dst_addr=0, token=1)
        return ops.wait_replies(ctx, st, token=1, n=2)

    rep = lint(gas, underflow, "underflow")
    check("R3 wait underflow flagged",
          rules_of(rep, analysis.ERROR) == ["R3"])

    def leak(st):
        return ops.put_long(ctx, st, pay, RING, dst_addr=0, token=1)

    rep = lint(gas, leak, "leak")
    check("R3 leaked credit warned",
          rules_of(rep, analysis.WARNING) == ["R3"])

    def double_spend(st):
        a = ctx.mailbox(RING, msg_words=4, token=3)
        b = ctx.mailbox(RING, msg_words=4, token=3)
        st = a.send(st, pay, dst_addr=0)
        st = a.flush(st)
        st = b.send(st, pay, dst_addr=16)
        st = b.flush(st)
        return ops.wait_replies(ctx, st, token=3, n=2)

    rep = lint(gas, double_spend, "double-spend")
    check("R3 cross-mailbox token double-spend warned",
          "R3" in rules_of(rep, analysis.WARNING))


# --------------------------------------------------------------------------
# R4: out-of-bounds + vectored aliasing (satellite: named ValueError)
# --------------------------------------------------------------------------

def test_r4_oob_and_vectored_alias():
    ctx, gas = make()

    def oob(st):
        st = ops.put_long(ctx, st, jnp.arange(50, dtype=jnp.float32), RING,
                          dst_addr=100, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    rep = lint(gas, oob, "oob")
    check("R4 out-of-bounds put flagged", "R4" in rules_of(rep))

    blocks = [jnp.ones(4, jnp.float32), jnp.ones(4, jnp.float32)]

    def aliasing(st):
        return ops.put_long_vectored(ctx, st, blocks, RING,
                                     dst_addrs=[8, 10], token=1,
                                     asynchronous=True)

    try:
        lint(gas, aliasing, "vectored-alias")
        raise AssertionError("overlapping dst_addrs did not raise")
    except ops.VectoredAliasError as e:
        check("R4 VectoredAliasError raised", "overlap" in str(e))

    def waived(st):
        with analysis.waiver("last-writer-wins is intended here"):
            st = aliasing(st)
        return st

    rep = lint(gas, waived, "vectored-alias-waived")
    check("R4 waiver downgrades raise to waived finding",
          rep.ok and len(rep.waived) == 1 and rep.waived[0].rule == "R4",
          f"(findings: {[f.render() for f in rep.findings]})")


# --------------------------------------------------------------------------
# registry entry points must all be clean (pass 1; pass 2 runs in CLI/CI)
# --------------------------------------------------------------------------

def test_registry_entries_clean():
    for name in registry.names():
        rep = registry.run_entry(name, include_hlo=False)
        check(f"entry {name} lints clean", rep.ok,
              f"({rep.n_events} events, {rep.tags_recovered} tags; "
              f"findings: {[f.render() for f in rep.findings]})")
        if name != "moe-dispatch":     # moe uses no shoal ops directly
            check(f"entry {name} tags recoverable from jaxpr",
                  rep.tags_recovered > 0 and rep.n_events > 0)


# --------------------------------------------------------------------------
# satellite 2: host-side debug surface for ERR_WAIT_UNDERFLOW
# --------------------------------------------------------------------------

def test_wait_underflow_host_exception():
    ctx, gas = make()

    def prog(st):
        return ops.wait_replies(ctx, st, token=5, n=3)

    st = jax.jit(gas.spmd(prog))(gas.make_global_state())
    try:
        raise_on_error(st, where="comm_lint_checks")
        raise AssertionError("raise_on_error did not raise")
    except WaitUnderflowError as e:
        check("WaitUnderflowError names the offending token",
              e.tokens == (5,), f"(tokens={e.tokens})")

    def clean(st):
        st = ops.put_long(ctx, st, jnp.arange(4, dtype=jnp.float32), RING,
                          dst_addr=0, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    st = jax.jit(gas.spmd(clean))(gas.make_global_state())
    check("raise_on_error passes a clean state",
          raise_on_error(st) is st)

    # the same broken schedule is caught statically, before any run
    try:
        jaxpr_lint.lint_clean(gas.spmd(prog), gas.make_global_state())
        raise AssertionError("lint_clean did not raise")
    except analysis.CommLintError as e:
        check("lint_clean raises CommLintError on the same schedule",
              "R3" in str(e))


def main():
    test_r1_strided_prefix_race()
    test_r1_unordered_write_pair()
    test_r2_get_vs_inflight_put()
    test_r3_credit_flow()
    test_r4_oob_and_vectored_alias()
    test_registry_entries_clean()
    test_wait_underflow_host_exception()
    print("COMM_LINT_CHECKS_ALL_PASS")


if __name__ == "__main__":
    main()
