"""Actor-layer tests: mailbox aggregation, metadata-lane coalescing,
host-side event batching.

Single-device unit tests run inline; the multi-device semantics and the
HLO collective budgets (1024 4-word sends -> <= 2 collectives, the PR's
acceptance criterion) run in a subprocess via tests/actor_checks.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_subprocess_checks

from repro.actors import (EventMailbox, Mailbox, SlotEvent, pack_meta_lane,
                          unpack_meta_lane)
from repro.core import am, handlers as hd, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime.topology import make_cpu_mesh

LOCAL = [(0, 0)]


def make_gas(segment_words=64):
    mesh = make_cpu_mesh(1, ("kernel",))
    ctx = ShoalContext(mesh=mesh, axes=("kernel",),
                       segment_words=segment_words)
    return ctx, GlobalAddressSpace(ctx)


# -- mailbox construction / argument validation --------------------------------

def test_mailbox_rejects_bad_args():
    ctx, _ = make_gas()
    with pytest.raises(TypeError, match="32-bit"):
        Mailbox(ctx, LOCAL, msg_words=4, dtype=jnp.bfloat16)
    with pytest.raises(ValueError, match="msg_words"):
        Mailbox(ctx, LOCAL, msg_words=0)
    with pytest.raises(ValueError, match="watermark"):
        Mailbox(ctx, LOCAL, msg_words=4, watermark=0)


def test_mailbox_send_validation():
    ctx, _ = make_gas()
    mb = Mailbox(ctx, LOCAL, msg_words=4)
    st = ctx.make_state()
    with pytest.raises(ValueError, match="exceeds msg_words"):
        mb.send(st, np.arange(5.0))
    with pytest.raises(ValueError, match="need a payload"):
        mb.send(st, None)
    with pytest.raises(ValueError, match="no payload"):
        mb.send(st, np.arange(2.0), msg_class=am.SHORT)
    with pytest.raises(ValueError, match="Medium"):
        mb.send(st, np.arange(2.0), msg_class=am.MEDIUM)
    assert mb.pending == 0  # failed sends enqueue nothing


def test_mailbox_flush_empty_is_noop():
    ctx, gas = make_gas()
    mb = Mailbox(ctx, LOCAL, msg_words=4)
    st = ctx.make_state()
    st2 = mb.flush(st)
    assert st2 is st and mb.flushes == 0


def test_mailbox_local_flush_semantics():
    """Single-kernel local pattern: payload rows + Short signals land
    per-row through the mixed-class stack ingress; one ack per flush."""
    ctx, gas = make_gas()

    def prog(st):
        mb = Mailbox(ctx, LOCAL, msg_words=4, watermark=100, token=5)
        st = mb.send(st, np.arange(1.0, 5.0), dst_addr=8)
        st = mb.send(st, np.asarray([2.0]), dst_addr=8, handler=hd.H_ADD)
        st = mb.send_signal(st, arg=4, token=7)
        st = mb.flush(st)
        return ops.wait_replies(ctx, st, token=5, n=1)

    out = jax.jit(gas.spmd(prog))(gas.make_global_state())
    seg = np.asarray(out.segment)[0]
    cred = np.asarray(out.credits)[0]
    np.testing.assert_allclose(seg[8:12], [3, 2, 3, 4])  # write then +2
    assert cred[7] == 4 and cred[5] == 0
    assert int(np.asarray(out.error)[0]) == 0


def test_context_mailbox_factories():
    ctx, _ = make_gas()
    assert isinstance(ctx.mailbox(LOCAL, msg_words=4), Mailbox)
    rmb = ctx.reply_mailbox()
    rmb.note(LOCAL, 3)
    rmb.note(LOCAL, 3)
    assert rmb.pending == 2

    def probe(t):  # a *traced* token cannot be coalesced at trace time
        with pytest.raises(ValueError, match="static"):
            rmb.note(LOCAL, t)
        return t

    jax.jit(probe)(jnp.asarray(3))
    assert rmb.pending == 2


# -- metadata-lane coalescing ---------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16,
                                   jnp.float16])
def test_meta_lane_roundtrip_exact(dtype):
    vals = jnp.asarray([0, 1, 2, 255, 256, 257, 1000, 32767, -5], jnp.int32)
    lane = pack_meta_lane(vals, dtype)
    assert lane.dtype == jnp.dtype(dtype)
    np.testing.assert_array_equal(np.asarray(unpack_meta_lane(lane)),
                                  np.asarray(vals))


def test_meta_lane_beats_value_cast():
    """The reason it's a bitcast: ids > 256 do not survive a bf16 value
    cast, but survive the lane packing bit-exactly."""
    ids = jnp.asarray([257, 511, 1023], jnp.int32)
    assert not np.array_equal(
        np.asarray(ids.astype(jnp.bfloat16).astype(jnp.int32)),
        np.asarray(ids))
    np.testing.assert_array_equal(
        np.asarray(unpack_meta_lane(pack_meta_lane(ids, jnp.bfloat16))),
        np.asarray(ids))


def test_meta_lane_rejects_odd_dtypes():
    with pytest.raises(TypeError):
        pack_meta_lane(jnp.zeros((2,), jnp.int32), jnp.int8)
    with pytest.raises(TypeError):
        unpack_meta_lane(jnp.zeros((2,), jnp.int8))


# -- host-side event mailbox ----------------------------------------------------

def test_event_mailbox_batches():
    batches = []
    mb = EventMailbox(watermark=3, sink=batches.append)
    for i in range(7):
        mb.send(SlotEvent("acquire", i % 2, i))
    assert [len(b) for b in batches] == [3, 3]
    assert mb.pending == 1
    mb.flush()
    assert [len(b) for b in batches] == [3, 3, 1]
    assert mb.sent == 7 and mb.flushes == 3
    assert mb.flush() == []  # empty flush is a no-op
    assert mb.flushes == 3


def test_serve_engine_emits_batched_slot_events():
    """The engine's slot accounting goes through the event mailbox: one
    sink call per decode step, acquire/release pairs per request."""
    from repro.models.model import ModelConfig, build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batches = []
    eng = ServeEngine(model, params, lanes=2, slots=32,
                      event_sink=batches.append)
    reqs = [Request(rid=i, prompt=np.arange(1, 4, dtype=np.int32) + i,
                    max_new=3) for i in range(3)]
    done = eng.run(reqs)
    assert len(done) == 3 and all(len(r.out) == 3 for r in done)
    events = [e for b in batches for e in b]
    acq = [e for e in events if e.kind == "acquire"]
    rel = [e for e in events if e.kind == "release"]
    assert sorted(e.rid for e in acq) == [0, 1, 2]
    assert sorted(e.rid for e in rel) == [0, 1, 2]
    # batching: fewer sink calls than events (the whole point)
    assert 0 < len(batches) < len(events)


# -- multi-device semantics + HLO budgets (subprocess) ---------------------------

def test_actor_checks_multidevice():
    out = run_subprocess_checks("actor_checks.py")
    assert "ACTOR_CHECKS_ALL_PASS" in out
