"""shoal-lint: behavioural checks (subprocess) + property test.

The subprocess half runs tests/comm_lint_checks.py on 8 host devices —
rules R1-R4 against real op-layer programs, the PR 6 strided-race
regression, registry cleanliness, and the host-side
``WaitUnderflowError`` debug path.

The property half fuzzes put/wait/barrier schedules and cross-checks
the analyzer's verdicts against ``sequential_schedule_oracle`` in
tests/actor_checks.py — an independent numpy executor that *runs* the
schedule under every admissible arrival reorder:

* R1 verdicts must equal the oracle's unordered-overlap pairs exactly;
* an R1-clean schedule must be arrival-order independent (every
  admissible reorder leaves final memory bit-identical);
* R3 underflow/leak verdicts must match the oracle's credit counters.
"""

import random

from _hypothesis_compat import given, settings, strategies
from conftest import run_subprocess_checks


def test_comm_lint_rules():
    out = run_subprocess_checks("comm_lint_checks.py", n_devices=8,
                                timeout=900)
    assert "COMM_LINT_CHECKS_ALL_PASS" in out


# --------------------------------------------------------------------------
# property: analyzer race/credit verdicts vs the numpy sequential oracle
# --------------------------------------------------------------------------

SEG = 16


def _random_schedule(rng: random.Random):
    n_ops = rng.randint(2, 10)
    sched, value = [], 1.0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.6:
            words = rng.randint(1, 5)
            sched.append(("put", rng.randrange(0, SEG - words), words,
                          value, rng.randint(0, 2), rng.random() < 0.7))
            value += 1.0           # distinct values: overlap is observable
        elif r < 0.85:
            sched.append(("wait", rng.randint(0, 2), rng.randint(1, 2)))
        else:
            sched.append(("barrier",))
    return sched


def _to_events(sched):
    from repro.analysis import CommEvent, Interval

    events = []
    for i, row in enumerate(sched):
        if row[0] == "put":
            _, start, words, _value, token, acked = row
            events.append(CommEvent(
                seq=i, op="put_long", pattern=((0, 1),),
                writes=(Interval(start, words),), token=token, acked=acked,
                segment_words=SEG))
        elif row[0] == "wait":
            events.append(CommEvent(seq=i, op="wait_replies", pattern=(),
                                    token=row[1], wait_n=row[2]))
        else:
            events.append(CommEvent(seq=i, op="barrier", pattern=()))
    return events


@settings(max_examples=120, deadline=None)
@given(seed=strategies.integers(min_value=0, max_value=2**20))
def test_race_verdicts_match_sequential_oracle(seed):
    from actor_checks import sequential_schedule_oracle
    from repro.analysis import ERROR, WARNING, lint_events

    sched = _random_schedule(random.Random(seed))
    oracle = sequential_schedule_oracle(sched, SEG)
    rep = lint_events(_to_events(sched), name=f"fuzz-{seed}")

    r1_pairs = {f.events for f in rep.findings if f.rule == "R1"}
    want = {(i, j) for i, j in oracle["unordered_overlaps"]}
    assert r1_pairs == want, (
        f"seed {seed}: R1 verdicts {sorted(r1_pairs)} != oracle "
        f"unordered overlaps {sorted(want)}\nschedule: {sched}")

    if not r1_pairs:
        # clean verdict is a *semantic* guarantee: executing the schedule
        # under any admissible arrival reorder gives identical memory
        assert not oracle["divergent"], (
            f"seed {seed}: analyzer clean but reorder changes memory: "
            f"{oracle['divergent']}\nschedule: {sched}")

    r3_under = {f.events[0] for f in rep.findings
                if f.rule == "R3" and f.severity == ERROR}
    assert r3_under == set(oracle["underflow_events"]), (
        f"seed {seed}: R3 underflows {sorted(r3_under)} != oracle "
        f"{oracle['underflow_events']}\nschedule: {sched}")

    n_leaks = sum(1 for f in rep.findings
                  if f.rule == "R3" and f.severity == WARNING)
    assert n_leaks == len(oracle["leaked_tokens"]), (
        f"seed {seed}: {n_leaks} R3 leak warnings != oracle leaked "
        f"tokens {oracle['leaked_tokens']}\nschedule: {sched}")
