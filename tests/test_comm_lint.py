"""shoal-lint: behavioural checks (subprocess) + property test.

The subprocess half runs tests/comm_lint_checks.py on 8 host devices —
rules R1-R4 against real op-layer programs, the PR 6 strided-race
regression, registry cleanliness, and the host-side
``WaitUnderflowError`` debug path.

The property half fuzzes schedules of puts (plain, defer_ack, and
two-stack put_long_multi calls), piggyback/drain ack grants, waits, and
barriers, and cross-checks the analyzer's verdicts against
``sequential_schedule_oracle`` in tests/actor_checks.py — an
independent numpy executor that *runs* the schedule under every
admissible arrival reorder:

* R1 verdicts must equal the oracle's unordered-overlap pairs exactly
  (for a deferred ack, a wait orders only once a piggyback/drain grant
  sits between put and wait);
* an R1-clean schedule must be arrival-order independent (every
  admissible reorder leaves final memory bit-identical);
* R3 underflow/leak/stranded-ledger verdicts must match the oracle's
  credit and ledger counters.
"""

import random

from _hypothesis_compat import given, settings, strategies
from conftest import run_subprocess_checks


def test_comm_lint_rules():
    out = run_subprocess_checks("comm_lint_checks.py", n_devices=8,
                                timeout=900)
    assert "COMM_LINT_CHECKS_ALL_PASS" in out


# --------------------------------------------------------------------------
# property: analyzer race/credit verdicts vs the numpy sequential oracle
# --------------------------------------------------------------------------

SEG = 16


def _random_schedule(rng: random.Random):
    n_ops = rng.randint(2, 10)
    sched, value, group = [], 1.0, 0
    while len(sched) < n_ops:
        r = rng.random()
        if r < 0.42:
            words = rng.randint(1, 5)
            sched.append(("put", rng.randrange(0, SEG - words), words,
                          value, rng.randint(0, 2), rng.random() < 0.7))
            value += 1.0           # distinct values: overlap is observable
        elif r < 0.56:
            # defer_ack put: the ack pools in the receiver ledger until a
            # piggyback/drain grant ships it home
            words = rng.randint(1, 5)
            sched.append(("put_defer", rng.randrange(0, SEG - words), words,
                          value, rng.randint(0, 2)))
            value += 1.0
        elif r < 0.66:
            kind = "piggyback" if rng.random() < 0.5 else "drain"
            sched.append((kind, rng.randint(0, 2)))
        elif r < 0.74:
            # one put_long_multi call: two stacks crossing as ONE
            # collective.  Same-call intervals are always disjoint — the
            # op raises VectoredAliasError for overlap at trace time.
            w1, w2 = rng.randint(1, 3), rng.randint(1, 3)
            s1 = rng.randrange(0, SEG - w1 - w2)
            s2 = rng.randrange(s1 + w1, SEG - w2 + 1)
            acked = rng.random() < 0.7
            sched.append(("put", s1, w1, value, rng.randint(0, 2), acked,
                          group))
            sched.append(("put", s2, w2, value + 1.0, rng.randint(0, 2),
                          acked, group))
            value += 2.0
            group += 1
        elif r < 0.9:
            sched.append(("wait", rng.randint(0, 2), rng.randint(1, 2)))
        else:
            sched.append(("barrier",))
    return sched


def _to_events(sched):
    from repro.analysis import CommEvent, Interval

    events = []
    for i, row in enumerate(sched):
        if row[0] == "put":
            start, words, _value, token, acked = row[1:6]
            grp = row[6] if len(row) > 6 else None
            events.append(CommEvent(
                seq=i, op="put_long" if grp is None else "put_long_multi",
                pattern=((0, 1),), writes=(Interval(start, words),),
                token=token, acked=acked, segment_words=SEG,
                detail={} if grp is None else {"group": grp}))
        elif row[0] == "put_defer":
            start, words, _value, token = row[1:5]
            events.append(CommEvent(
                seq=i, op="put_long", pattern=((0, 1),),
                writes=(Interval(start, words),), token=token, acked=True,
                defer_ack=True, segment_words=SEG))
        elif row[0] == "piggyback":
            # the reverse-link data packet whose header lane carries the
            # ledgered acks home; the carrier itself earns no credit
            events.append(CommEvent(
                seq=i, op="put_long", pattern=((1, 0),), writes=(),
                token=row[1], acked=False, asynchronous=True,
                piggyback_token=row[1], segment_words=SEG))
        elif row[0] == "drain":
            events.append(CommEvent(
                seq=i, op="drain_deferred_acks", pattern=((1, 0),),
                token=row[1], acked=False, asynchronous=True,
                drains_deferred=True))
        elif row[0] == "wait":
            events.append(CommEvent(seq=i, op="wait_replies", pattern=(),
                                    token=row[1], wait_n=row[2]))
        else:
            events.append(CommEvent(seq=i, op="barrier", pattern=()))
    return events


@settings(max_examples=120, deadline=None)
@given(seed=strategies.integers(min_value=0, max_value=2**20))
def test_race_verdicts_match_sequential_oracle(seed):
    from actor_checks import sequential_schedule_oracle
    from repro.analysis import ERROR, WARNING, lint_events

    sched = _random_schedule(random.Random(seed))
    oracle = sequential_schedule_oracle(sched, SEG)
    rep = lint_events(_to_events(sched), name=f"fuzz-{seed}")

    r1_pairs = {f.events for f in rep.findings if f.rule == "R1"}
    want = {(i, j) for i, j in oracle["unordered_overlaps"]}
    assert r1_pairs == want, (
        f"seed {seed}: R1 verdicts {sorted(r1_pairs)} != oracle "
        f"unordered overlaps {sorted(want)}\nschedule: {sched}")

    if not r1_pairs:
        # clean verdict is a *semantic* guarantee: executing the schedule
        # under any admissible arrival reorder gives identical memory
        assert not oracle["divergent"], (
            f"seed {seed}: analyzer clean but reorder changes memory: "
            f"{oracle['divergent']}\nschedule: {sched}")

    r3_under = {f.events[0] for f in rep.findings
                if f.rule == "R3" and f.severity == ERROR}
    assert r3_under == set(oracle["underflow_events"]), (
        f"seed {seed}: R3 underflows {sorted(r3_under)} != oracle "
        f"{oracle['underflow_events']}\nschedule: {sched}")

    # R3 warnings = one per leaked token (credits never waited) + one
    # per stranded token (deferred acks never piggybacked/drained)
    n_warn = sum(1 for f in rep.findings
                 if f.rule == "R3" and f.severity == WARNING)
    want_warn = len(oracle["leaked_tokens"]) + len(oracle["stranded_acks"])
    assert n_warn == want_warn, (
        f"seed {seed}: {n_warn} R3 warnings != oracle leaked "
        f"{oracle['leaked_tokens']} + stranded {oracle['stranded_acks']}"
        f"\nschedule: {sched}")
