"""Math oracles for the recurrent blocks: the chunkwise/scan-parallel
forms must match naive sequential recurrences."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recurrent import _lru_scan
from repro.models.xlstm import _chunk_mlstm

RNG = np.random.default_rng(0)


def test_lru_scan_matches_sequential():
    B, S, D = 2, 33, 8
    a = jnp.asarray(RNG.uniform(0.5, 0.99, (B, S, D)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
    got = _lru_scan(a, b)
    h = np.zeros((B, D), np.float32)
    want = np.zeros((B, S, D), np.float32)
    an, bn = np.asarray(a), np.asarray(b)
    for t in range(S):
        h = an[:, t] * h + bn[:, t]
        want[:, t] = h
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def _mlstm_sequential(q, k, v, logf, logi):
    """Naive stabilized mLSTM recurrence (xLSTM paper eqs.)."""
    B, S, nh, dh = q.shape
    C = np.zeros((B, nh, dh, dh), np.float64)
    n = np.zeros((B, nh, dh), np.float64)
    m = np.full((B, nh), -1e30)
    out = np.zeros((B, S, nh, dh), np.float64)
    q, k, v = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    logf, logi = np.asarray(logf, np.float64), np.asarray(logi, np.float64)
    for t in range(S):
        m_new = np.maximum(logf[:, t] + m, logi[:, t])
        f = np.exp(logf[:, t] + m - m_new)
        i = np.exp(logi[:, t] - m_new)
        C = f[..., None, None] * C + i[..., None, None] * np.einsum(
            "bhd,bhe->bhde", k[:, t], v[:, t])
        n = f[..., None] * n + i[..., None] * k[:, t]
        m = m_new
        num = np.einsum("bhd,bhde->bhe", q[:, t], C) / np.sqrt(dh)
        den = np.abs(np.einsum("bhd,bhd->bh", q[:, t], n)) / np.sqrt(dh)
        den = np.maximum(den, np.exp(-m))
        out[:, t] = num / den[..., None]
    return out


def test_chunk_mlstm_matches_sequential():
    B, S, nh, dh = 1, 32, 2, 8
    q = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    logf = jnp.asarray(np.log(RNG.uniform(0.6, 0.95, (B, S, nh))), jnp.float32)
    logi = jnp.asarray(RNG.standard_normal((B, S, nh)) * 0.5, jnp.float32)
    got, final = _chunk_mlstm(q, k, v, logf, logi, chunk=8)
    want = _mlstm_sequential(q, k, v, logf, logi)
    # the chunk form uses a per-sequence stabilizer (vs running max), so
    # the DENOMINATOR FLOOR can differ when |q.n| is tiny; tolerances are
    # loose there but the bulk must agree tightly.
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=2e-2, atol=2e-2)


def test_chunk_mlstm_final_state_continues():
    """Chunked prefill final state == sequential recurrence state, so a
    decode continuation is consistent."""
    B, S, nh, dh = 1, 16, 2, 4
    q = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, nh, dh)), jnp.float32)
    logf = jnp.asarray(np.log(RNG.uniform(0.7, 0.95, (B, S, nh))), jnp.float32)
    logi = jnp.asarray(RNG.standard_normal((B, S, nh)) * 0.3, jnp.float32)
    _, (C_T, n_T, m_T) = _chunk_mlstm(q, k, v, logf, logi, chunk=4)
    # sequential reference state (rescale both to the unstabilized frame)
    Cs = np.zeros((B, nh, dh, dh)); ns = np.zeros((B, nh, dh))
    lf, li = np.asarray(logf, np.float64), np.asarray(logi, np.float64)
    kn, vn = np.asarray(k, np.float64), np.asarray(v, np.float64)
    for t in range(S):
        f = np.exp(lf[:, t]); i = np.exp(li[:, t])
        Cs = f[..., None, None] * Cs + i[..., None, None] * np.einsum(
            "bhd,bhe->bhde", kn[:, t], vn[:, t])
        ns = f[..., None] * ns + i[..., None] * kn[:, t]
    scale = np.exp(np.asarray(m_T, np.float64))          # C_true = e^m C_stab
    np.testing.assert_allclose(np.asarray(C_T, np.float64)
                               * scale[..., None, None], Cs, rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(n_T, np.float64)
                               * scale[..., None], ns, rtol=1e-3, atol=1e-3)
