"""Fallback for ``hypothesis`` on bare environments.

Test modules import ``given`` / ``settings`` / ``strategies`` from here
instead of from ``hypothesis`` directly.  When the real package is
installed it is re-exported unchanged (full shrinking/fuzzing).  When it
is absent, a minimal fixed-example shim takes over: each ``@given`` test
runs against a deterministic sample of the declared strategies —
boundary values first, then a seeded pseudo-random sweep — so the suite
still exercises the property across a meaningful spread of inputs
without the dependency.
"""

from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import inspect
    import itertools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A deterministic example source standing in for a hypothesis
        strategy: ``boundary`` examples always run; the rest are drawn
        from ``sample(rng)``."""

        def __init__(self, boundary, sample):
            self.boundary = list(boundary)
            self.sample = sample

        def examples(self, rng: random.Random, n: int):
            out = list(self.boundary[:n])
            while len(out) < n:
                out.append(self.sample(rng))
            return out

    class _Strategies:
        @staticmethod
        def integers(min_value=-(2**31), max_value=2**31 - 1):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            return _Strategy([lo, hi, mid], lambda rng: rng.randint(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(elements, lambda rng: rng.choice(elements))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy([lo, hi], lambda rng: rng.uniform(lo, hi))

    strategies = _Strategies()

    def settings(*, max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        """Record ``max_examples``; everything else is meaningless here."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test against a fixed example matrix: the cartesian
        product of boundary values is sampled first (capped), then
        seeded-random draws fill up to ``max_examples``."""

        def deco(fn):
            def wrapper(*args, **kwargs):
                # @settings sits *outside* @given, so it stamps the wrapper
                n = getattr(wrapper, "_compat_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0xA11CE)
                names = sorted(strats)
                # a few joint boundary combinations, then random draws
                combos = list(itertools.islice(
                    itertools.product(*(strats[k].boundary for k in names)),
                    max(1, n // 2)))
                while len(combos) < n:
                    combos.append(tuple(strats[k].sample(rng) for k in names))
                for combo in combos:
                    case = dict(zip(names, combo))
                    case.update(kwargs)
                    fn(*args, **case)

            # The strategy kwargs are filled here, not by pytest fixtures:
            # expose a parameterless signature (and no __wrapped__).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = inspect.Signature()
            # Mimic hypothesis' introspection surface: plugins (anyio,
            # pytest-asyncio) reach for ``fn.hypothesis.inner_test``.
            wrapper.hypothesis = type("_H", (), {"inner_test": fn})()
            return wrapper

        return deco
