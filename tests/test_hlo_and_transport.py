"""HLO collective parser unit tests + the analytic transport model."""

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.launch.hlo_analysis import parse_collectives, split_computations
from repro.runtime.router import Router
from repro.runtime.topology import ClusterSpec, neighbors_ring, pairwise
from repro.runtime.transport import (TCP, UDP, LinkClass, model_latency_s,
                                     model_throughput_Bps)

MINI_HLO = """\
HloModule jit_f

%add.1 (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %r = f32[] add(%x, %y)
}

%body.2 (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p = (s32[], f32[8,4]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,4]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,4]{1,0} all-reduce(%g1), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %d = f32[8,8]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %dd = f32[8,4]{1,0} slice(%d), slice={[0:8], [0:4]}
  ROOT %t = (s32[], f32[8,4]) tuple(%g0, %dd)
}

%cond.3 (p: (s32[], f32[8,4])) -> pred[] {
  %p = (s32[], f32[8,4]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%g0, %c), direction=LT
}

ENTRY %main.4 (a: f32[8,4]) -> f32[8,4] {
  %a = f32[8,4]{1,0} parameter(0)
  %cp = f32[8,4]{1,0} collective-permute(%a), source_target_pairs={{0,1},{1,2}}
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,4]) tuple(%zero, %cp)
  %w = (s32[], f32[8,4]) while(%tup), condition=%cond.3, body=%body.2
  ROOT %out = f32[8,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parser_trip_weighting():
    stats = parse_collectives(MINI_HLO)
    # all-reduce runs 10x (while trip count), permute once
    assert stats.ops["all-reduce"] == 10.0
    assert stats.ops["collective-permute"] == 1.0
    ar_bytes = 8 * 4 * 4
    # wire: AR 2(n-1)/n with n=4 -> 1.5x, CP 1x
    expected = 10 * ar_bytes * 1.5 + ar_bytes * 1.0
    assert stats.wire_bytes == pytest.approx(expected)
    # dot: 2 * 8*8 * 4 contraction, 10 trips
    assert stats.dot_flops == pytest.approx(10 * 2 * 64 * 4)


def test_split_computations_names():
    comps = split_computations(MINI_HLO)
    assert set(comps) == {"add.1", "body.2", "cond.3", "main.4"}


# -- transport / router -------------------------------------------------------

def test_router_link_classes():
    spec = ClusterSpec((2, 4), ("pod", "chip"), pod_axis="pod")
    r = Router(spec)
    assert r.classify(0, 0) == LinkClass.LOCAL
    assert r.classify(0, 1) == LinkClass.ICI         # same pod
    assert r.classify(0, 4) == LinkClass.DCN         # cross pod
    assert r.classify_pattern([(0, 1), (1, 5)]) == LinkClass.DCN
    assert r.is_pure_local([(0, 0), (1, 1)])


def test_latency_model_ordering():
    """The paper's qualitative results: async (UDP) < acked (TCP), and
    LOCAL < ICI < DCN, and latency grows with payload."""
    for link in LinkClass:
        assert (model_latency_s(UDP, link, 1024)
                < model_latency_s(TCP, link, 1024))
    for t in (TCP, UDP):
        assert (model_latency_s(t, LinkClass.LOCAL, 256)
                < model_latency_s(t, LinkClass.ICI, 256)
                < model_latency_s(t, LinkClass.DCN, 256))
        assert (model_latency_s(t, LinkClass.ICI, 8)
                < model_latency_s(t, LinkClass.ICI, 4096))


def test_throughput_model_grows_with_payload():
    small = model_throughput_Bps(TCP, LinkClass.ICI, 8)
    large = model_throughput_Bps(TCP, LinkClass.ICI, 4096)
    assert large > small
    assert large < TCP.bw_Bps[LinkClass.ICI.value]


def test_mtu_words():
    assert TCP.max_packet_words == 2250     # 9000-byte jumbo frame / 4


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 64), shift=st.integers(1, 8))
def test_ring_pattern_is_permutation(n, shift):
    ring = neighbors_ring(n, shift)
    pairwise(ring)   # no duplicate src/dst
    assert sorted(s for s, _ in ring) == list(range(n))
    assert sorted(d for _, d in ring) == list(range(n))


def test_pairwise_rejects_duplicates():
    with pytest.raises(ValueError):
        pairwise([(0, 1), (0, 2)])


def test_segments_plan():
    from repro.core.ops import _segments
    plan = _segments(50, 16)
    assert plan == [(0, 16), (16, 16), (32, 16), (48, 2)]
    assert _segments(16, 16) == [(0, 16)]


def test_address_space_math():
    from repro.core.address_space import GlobalAddressSpace
    from repro.core.state import ShoalContext
    from repro.runtime.topology import make_cpu_mesh
    ctx = ShoalContext(mesh=make_cpu_mesh(1, ("kernel",)), axes=("kernel",),
                       segment_words=128)
    gas = GlobalAddressSpace(ctx)
    g = gas.global_addr(0, 37)
    assert gas.owner_of(g) == 0 and gas.local_offset(g) == 37
    with pytest.raises(ValueError):
        gas.global_addr(0, 128)
