"""Regression: the fused single-packet wire format keeps the compiled
collective budget — ≤ nseg + 1 collective-permutes per acked >MTU AM
(measured at 2: one batched packet stack + one coalesced reply), down
from 3·nseg in the header/payload/reply-per-segment model.  Compiled in
a subprocess with 8 host devices; see tests/hlo_budget_checks.py."""

from conftest import run_subprocess_checks


def test_collective_budget():
    out = run_subprocess_checks("hlo_budget_checks.py", n_devices=8,
                                timeout=900)
    assert "HLO_BUDGET_OK" in out
