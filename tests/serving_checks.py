"""Multi-kernel disaggregated-serving checks (subprocess, 4 host devices).

Run by tests/test_serving_disagg.py via conftest.run_subprocess_checks:

* the compiled KV-migration program costs exactly 2 collective-permutes
  (1 fused vectored packet with the per-layer address list in-packet +
  1 coalesced reply) — the PR's collective-budget acceptance gate;
* requests served through the tier — prefill on the prefill slice, ONE
  vectored put into the decode kernel's segment, adoption on a decode
  lane — decode to exactly the tokens the single-host in-place engine
  produces (ragged prompts, mixed lane progress, both decode kernels);
* no sticky error bits anywhere (in particular no wait-underflow from
  the sender-side-only migration reply);
* the admission front-end over the tier: queue depth stays bounded,
  rejected jobs are visible, admitted jobs complete via slot events.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import ServingSlices
from repro.models.model import ModelConfig, build_model
from repro.serving import (DONE, REJECTED, Request, ServeEngine,
                           ServeFrontend)
from repro.serving.disagg import DisaggServeTier

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   dtype=jnp.float32)
SLOTS = 16

PROMPTS = [[3, 14, 15, 9, 2], [7, 8], [30, 2, 9], [11, 12, 13, 5],
           [1, 4], [22, 40, 8]]
MAX_NEW = [5, 3, 4, 5, 3, 4]


def check_migration_budget(tier):
    for src, dst in [(0, 2), (1, 3)]:
        hlo = tier.migration_hlo(src, dst, lane=0)
        cps = parse_collectives(hlo).ops.get("collective-permute", 0.0)
        assert cps == 2, (f"migration {src}->{dst}: {cps:.0f} "
                          "collective-permutes != 2 (1 vectored packet "
                          "+ 1 coalesced reply)")
        print(f"[serving] migrate {src}->{dst}: {cps:.0f} "
              "collective-permutes == 2 ok")


def check_bit_identity(model, params, tier):
    reqs = [Request(i, np.asarray(p, np.int32), m)
            for i, (p, m) in enumerate(zip(PROMPTS, MAX_NEW))]
    done = tier.run(reqs)
    assert len(done) == len(reqs)
    assert tier.migrations == len(reqs)
    # every kernel's sticky error word must be clean — in particular no
    # ERR_WAIT_UNDERFLOW from the migration reply on non-sender kernels
    err = np.asarray(jax.device_get(tier.state.error))
    assert (err == 0).all(), f"sticky error bits set: {err}"
    # oracle: the same request solo on a single-host in-place engine
    oracle = ServeEngine(model, params, lanes=1, slots=SLOTS)
    for req in reqs:
        ref = Request(req.rid, req.prompt, req.max_new)
        oracle.run([ref])
        assert req.out == ref.out, (
            f"rid {req.rid}: migrated decode {req.out} != oracle {ref.out}")
        assert len(req.out) == req.max_new
    print(f"[serving] {len(reqs)} migrated requests bit-identical to the "
          "single-host oracle")


def check_frontend(tier):
    fe = ServeFrontend(tier, max_queue=2)
    jobs = [fe.submit(p, m) for p, m in zip(PROMPTS, MAX_NEW)]
    rejected = [j for j in jobs if j.status == REJECTED]
    assert rejected, "expected backpressure with max_queue=2 and 6 submits"
    fe.run_until_idle()
    # retry the rejected ones, pumping between attempts so the bounded
    # queue drains — the backpressure contract from the caller's side
    retries, pending = [], [(list(j.request.prompt), j.request.max_new)
                           for j in rejected]
    while pending:
        job = fe.submit(*pending[0])
        if job.status == REJECTED:
            fe.pump()
            continue
        pending.pop(0)
        retries.append(job)
    fe.run_until_idle()
    admitted = [j for j in jobs if j.status != REJECTED] + retries
    assert all(j.status == DONE for j in admitted)
    assert fe.peak_queue_depth <= fe.max_queue
    stats = fe.stats()
    assert stats["busy_lanes"] == 0 and stats["queue_depth"] == 0
    print(f"[serving] frontend: {stats['admitted']} admitted, "
          f"{stats['rejected']} rejected, peak queue depth "
          f"{fe.peak_queue_depth} <= {fe.max_queue}")


def main():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    slices = ServingSlices(n_prefill=2, n_decode=2)
    tier = DisaggServeTier(model, params, slices, lanes_per_decode=2,
                           slots=SLOTS)
    print("[serving] " + tier.kv.describe().splitlines()[0])
    check_migration_budget(tier)
    check_bit_identity(model, params, tier)
    check_frontend(tier)
    print("SERVING_CHECKS_OK")


if __name__ == "__main__":
    main()
