"""One real dry-run cell end-to-end in a subprocess (512 host devices):
proves the production-mesh lowering path stays green in CI.  Uses the
fastest cell (xlstm decode)."""

import json
import os
import subprocess
import sys

from conftest import REPO


def test_dryrun_one_cell(tmp_path):
    out = tmp_path / "cell.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)   # dryrun sets its own device count
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-350m", "--shape", "decode_32k",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    pd = rec["per_device"]
    assert pd["flops"] > 0
    assert pd["peak_bytes"] > 0
    assert rec["mesh"] == {"data": 16, "model": 16}
