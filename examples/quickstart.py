"""Quickstart: the Shoal PGAS API in 60 lines.

Emulates an 8-kernel cluster on CPU, then: one-sided puts, a remote
accumulate, a get, a barrier, and a ring all-reduce built from puts.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.core import collectives, handlers as hd, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.runtime import TCP, make_cpu_mesh

N = 8
mesh = make_cpu_mesh(N, ("kernel",))
ctx = ShoalContext(mesh=mesh, axes=("kernel",), transport=TCP,
                   segment_words=64)
gas = GlobalAddressSpace(ctx)
ring = [(i, (i + 1) % N) for i in range(N)]


def program(state):
    me = ctx.my_id()
    # 1. one-sided put: my rank, times 4 words, into my successor's segment
    payload = jnp.full((4,), me + 1, jnp.float32)
    state = ops.put_long(ctx, state, payload, ring, dst_addr=0, token=1)
    state = ops.wait_replies(ctx, state, token=1, n=1)
    # 2. remote accumulate (Long put with the ADD handler)
    state = ops.put_long(ctx, state, jnp.ones(4), ring, dst_addr=0,
                         handler=hd.H_ADD, token=2)
    state = ops.wait_replies(ctx, state, token=2, n=1)
    # 3. barrier, then one-sided get from my successor
    state = ops.barrier(ctx, state)
    state, fetched = ops.get_medium(ctx, state, ring, src_addr=0, nwords=4,
                                    token=3)
    state = ops.wait_replies(ctx, state, token=3, n=1)
    from repro.core.gascore import dataclasses_replace
    state = dataclasses_replace(
        state, segment=jax.lax.dynamic_update_slice(state.segment, fetched,
                                                    (8,)))
    return state


state = jax.jit(gas.spmd(program))(gas.make_global_state())
seg = np.asarray(state.segment)
print("segment[0:4] per kernel (predecessor rank+1, +1 accumulated):")
print(seg[:, 0:4])
print("fetched from successor (segment[8:12]):")
print(seg[:, 8:12])

# ring all-reduce built from one-sided puts
xs = jnp.arange(N * 4, dtype=jnp.float32).reshape(N, 4)
total = jax.jit(shard_map(
    lambda x: collectives.ring_all_reduce(x, ("kernel",), N), mesh=mesh,
    in_specs=P("kernel"), out_specs=P("kernel")))(xs)
print("ring all-reduce (every kernel holds the column sums):")
print(np.asarray(total)[0], "== expected", np.asarray(xs).sum(0))
assert np.allclose(np.asarray(total)[0], np.asarray(xs).sum(0))
print("quickstart OK")
