"""Serving example: batched requests with continuous-batching lanes.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.exit(serve_main(["--arch", "qwen2-1.5b", "--reduced",
                         "--requests", "6", "--lanes", "2",
                         "--max-new", "12"]))
