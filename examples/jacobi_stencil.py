"""The paper's Jacobi application (Sec. IV-C) on Shoal.

Partitions a 512x512 grid over 4 kernels, runs 64 iterations with
one-sided halo exchange, checks against the single-kernel oracle, and
shows the same source running on 1..8 kernels — the paper's "one source
file, any topology" claim.

    PYTHONPATH=src python examples/jacobi_stencil.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

from repro.apps.jacobi import JacobiApp, jacobi_reference

N, ITERS = 512, 64
rng = np.random.default_rng(0)
grid = rng.standard_normal((N, N)).astype(np.float32)
ref = jacobi_reference(grid.copy(), ITERS)

for kernels in [1, 2, 4, 8]:
    app = JacobiApp(n=N, kernels=kernels, iters=ITERS)
    t0 = time.perf_counter()
    out = app.run(grid.copy())
    dt = time.perf_counter() - t0
    err = np.abs(out - ref).max()
    print(f"kernels={kernels}:  {dt*1e3:7.1f} ms   max|err|={err:.2e}")
    assert err < 1e-5

print("jacobi example OK (same source, four topologies)")
