"""End-to-end training driver: train a small LM for a few hundred steps
on CPU, with checkpointing, an injected node failure, and automatic
recovery — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys
import tempfile

from repro.launch.train import main as launch_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as d:
        rc = launch_main([
            "--arch", args.arch, "--reduced",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "64",
            "--ckpt-dir", d, "--ckpt-every", "25", "--log-every", "10",
            # inject a "node failure" mid-run: the launcher restores the
            # last checkpoint (with the data-pipeline position) and resumes
            "--fail-at", str(args.steps // 2),
        ])
    sys.exit(rc)
