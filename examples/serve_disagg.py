"""Disaggregated serving: prefill/decode slices + PGAS KV migration.

Emulates a 4-kernel cluster (2 prefill + 2 decode kernels, 2 lanes per
decode kernel).  Each request is prefilled on the prefill slice; its
ring KV cache — laid out in the global address space by KvSegmentSpace —
migrates to a free decode lane as ONE put_long_vectored (per-layer
destination addresses ride in-packet), and the admission front-end
shows queue backpressure and slot-event-driven completion.

    PYTHONPATH=src python examples/serve_disagg.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import ServingSlices
from repro.models.model import ModelConfig, build_model
from repro.serving import REJECTED, ServeFrontend
from repro.serving.disagg import DisaggServeTier

cfg = ModelConfig(name="demo", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                  dtype=jnp.float32)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

slices = ServingSlices(n_prefill=2, n_decode=2)
tier = DisaggServeTier(model, params, slices, lanes_per_decode=2, slots=16)

print("KV segment layout (per decode kernel):")
print(tier.kv.describe())

hlo = tier.migration_hlo(0, slices.decode_ids[0], lane=0)
cps = parse_collectives(hlo).ops.get("collective-permute", 0.0)
print(f"\none KV migration compiles to {cps:.0f} collective-permutes "
      "(1 fused vectored packet + 1 coalesced reply)")

fe = ServeFrontend(tier, max_queue=3)
rng = np.random.default_rng(0)
jobs = [fe.submit(list(rng.integers(1, cfg.vocab, size=int(n))), max_new=5)
        for n in rng.integers(2, 7, size=8)]
print(f"\nsubmitted 8 requests, queue bound 3: "
      f"{sum(j.status == REJECTED for j in jobs)} rejected (backpressure)")

fe.run_until_idle()
for job in jobs:
    if job.status == REJECTED:
        print(f"  rid {job.rid}: rejected (retry later)")
    else:
        print(f"  rid {job.rid}: {job.status} tokens={fe.result(job.rid)}")
stats = fe.stats()
print(f"\n{stats['admitted']} admitted / {stats['rejected']} rejected, "
      f"peak queue depth {stats['peak_queue_depth']}, "
      f"{tier.migrations} KV migrations")
