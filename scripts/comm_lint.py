#!/usr/bin/env python
"""shoal-lint CLI: run both comm-safety passes over registered entry
points (see README "Static analysis").

Pass 1 re-traces each program under an event recorder and checks rules
R1-R4 (races, credit flow, addressing); pass 2 compiles it and diffs
collective counts/bytes against ``comm_budgets.toml`` (rule B1).  Any
unwaived finding exits non-zero — this is the CI gate.

Usage::

    python scripts/comm_lint.py                    # all entries
    python scripts/comm_lint.py --entry jacobi --entry kv-migrate
    python scripts/comm_lint.py --list
    python scripts/comm_lint.py --json out.json    # machine-readable

Must set the forced host-device count before jax imports, so keep the
os.environ block above every repro/jax import.
"""

import argparse
import json
import os
import sys
import time


def _force_devices(n: int) -> None:
    flag = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--entry", action="append", default=None,
                    help="entry point to lint (repeatable; default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered entry points and exit")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable report to PATH")
    ap.add_argument("--budgets", metavar="TOML", default=None,
                    help="budget file (default: repo comm_budgets.toml)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip pass 2 (no compile, jaxpr lint only)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (default 8)")
    args = ap.parse_args(argv)

    _force_devices(args.devices)
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

    from repro.analysis import hlo_budget, registry

    if args.list:
        for e in registry.ENTRIES:
            print(f"{e.name:16s} {e.description}")
        return 0

    names = args.entry or registry.names()
    budgets = None
    if not args.no_hlo:
        budgets = hlo_budget.load_budgets(args.budgets)

    t0 = time.perf_counter()
    doc = {"entries": {}, "total_wall_time_s": 0.0}
    failed = False
    for name in names:
        rep = registry.run_entry(name, budgets=budgets,
                                 include_hlo=not args.no_hlo)
        print(rep.render())
        failed = failed or not rep.ok
        doc["entries"][name] = {
            "ok": rep.ok,
            "n_events": rep.n_events,
            "tags_recovered": rep.tags_recovered,
            "wall_time_s": round(rep.wall_time_s, 3),
            "findings": [f.render() for f in rep.findings],
            "budget": rep.budget,
        }
    doc["total_wall_time_s"] = round(time.perf_counter() - t0, 3)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")

    print(f"shoal-lint: {len(names)} entr{'y' if len(names) == 1 else 'ies'} "
          f"in {doc['total_wall_time_s']:.1f}s: "
          f"{'FINDINGS' if failed else 'clean'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
