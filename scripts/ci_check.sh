#!/usr/bin/env bash
# Pre-merge gate: tier-1 tests + collective-budget tests + benchmark
# smoke mode (collective-permute budgets incl. the mailbox
# messages-per-collective floor).  Run from anywhere; exits non-zero on
# the first failure.
set -euo pipefail

REPO="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$REPO"
export PYTHONPATH="$REPO/src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== shoal-lint (comm-safety + collective budgets) =="
python scripts/comm_lint.py

echo "== collective budget tests =="
python -m pytest -x -q tests/test_collective_budget.py

echo "== serving tier tests (disaggregated prefill/decode) =="
python -m pytest -x -q tests/test_serving_disagg.py

echo "== benchmark smoke (collective budgets) =="
python benchmarks/run.py --smoke

echo "== serving smoke (migration budget, bounded queue) =="
python benchmarks/run.py --serving

echo "== fault suite (CRC, retransmit/dedup, graceful degradation) =="
python -m pytest -x -q tests/test_faults.py

echo "== fault sweep (goodput + retransmit budgets under loss) =="
python benchmarks/run.py --faults

echo CI_CHECK_OK
