"""Hillclimb driver: run one dry-run cell with config/trainer overrides
and print the roofline deltas vs the baseline JSONL.

    PYTHONPATH=src python scripts/hillclimb.py --arch tinyllama-1.1b \
        --shape train_4k --set tp=False --tag no-tp
"""

import argparse
import dataclasses
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--set", nargs="*", default=[],
                    help="ModelConfig overrides, e.g. tp=False remat=full")
    ap.add_argument("--tag", default="hc")
    ap.add_argument("--out", default="dryrun_hillclimb.jsonl")
    ap.add_argument("--baseline", default="dryrun_baseline.jsonl")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell          # sets XLA_FLAGS first
    from repro import configs

    cfg = configs.full(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v in ("1", "true", "True")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        overrides[k] = v
    cfg = dataclasses.replace(cfg, **overrides)

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   comm_backend=args.backend, override_cfg=cfg,
                   microbatches=args.microbatches)
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    if rec["status"] != "ok":
        print("FAILED:", rec.get("error"))
        return 1

    # compare vs baseline
    base = None
    try:
        for line in open(args.baseline):
            r = json.loads(line)
            if (r["arch"], r["shape"], r["multi_pod"], r.get("backend")) == \
               (args.arch, args.shape, args.multi_pod, "xla") \
               and r["status"] == "ok":
                base = r
    except FileNotFoundError:
        pass
    pd = rec["per_device"]
    print(f"[{args.tag}] {args.arch} x {args.shape} "
          f"{'2pod' if args.multi_pod else '1pod'}")

    def fmt(d):
        return (f"flops={max(d['flops'], d.get('dot_flops_weighted', 0))/1e12:.2f}TF "
                f"bytes={d['bytes_accessed']/1e9:.1f}GB "
                f"wire={d['collective_wire_bytes']/1e9:.2f}GB "
                f"peak={d['peak_bytes']/1e9:.2f}GB")

    if base:
        print("  base:", fmt(base["per_device"]))
    print("  new: ", fmt(pd))
    if base:
        b, n = base["per_device"], pd
        for k, lbl in (("collective_wire_bytes", "wire"),
                       ("peak_bytes", "peak"), ("bytes_accessed", "hbm")):
            if b[k]:
                print(f"  {lbl}: {n[k]/b[k]:.3f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
