from repro.training.train import TrainState, Trainer, TrainerConfig

__all__ = ["TrainState", "Trainer", "TrainerConfig"]
