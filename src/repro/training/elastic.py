"""Fault tolerance at step granularity: straggler quorum, elastic restart.

At 1000+ nodes the failure model is: (a) a host dies mid-run — handled
by checkpoint/restart with the data-pipeline step inside the checkpoint
(:mod:`repro.checkpoint`) plus the launcher retry loop
(:mod:`repro.launch.train`); (b) a host is *slow* (straggler) — handled
within-step by compute/comm overlap (bucketed grads, latency-hiding
scheduler) and across steps by **quorum DP**: the step proceeds with
whichever DP ranks contributed, reweighting the mean by the live count.
On a real deployment the live mask comes from the coordination service
heartbeat; here it is an input, which also makes the policy unit-testable
and lets tests inject failures deterministically.

A third failure mode arrived with lossy transports (c): a rank is alive
but its *communication* failed — a reliable put exhausted its
retransmit budget and latched the sticky ``ERR_RETRY_EXHAUSTED`` bit.
:func:`delivery_live_mask` folds that into the quorum: ranks whose
delivery failed drop out of the live mask exactly like stragglers, so
one bad link degrades the batch instead of corrupting the mean with a
half-delivered contribution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import ERR_RETRY_EXHAUSTED


def quorum_mean_grads(grads, live: jnp.ndarray, axes):
    """Mean-of-live gradient reduction (inside shard_map over ``axes``).

    ``live``: () float {0,1} for this DP rank.  Dead ranks contribute
    zero; the sum is renormalized by the live count, so the update
    equals the mean over surviving ranks (drop-straggler semantics).
    """
    n_live = jax.lax.psum(live, axes)

    def one(g):
        g = g.astype(jnp.float32) * live
        return (jax.lax.psum(g, axes) / jnp.maximum(n_live, 1.0)).astype(g.dtype)

    return jax.tree.map(one, grads), n_live


def delivery_live_mask(live: jnp.ndarray, error: jnp.ndarray,
                       bits: int = ERR_RETRY_EXHAUSTED) -> jnp.ndarray:
    """Fold comm-delivery failure into a quorum live mask.

    ``live`` is this rank's heartbeat mask (() float {0,1}); ``error``
    the rank's sticky PGAS error word (``PgasState.error``).  A rank
    whose reliable put gave up (``ERR_RETRY_EXHAUSTED`` by default —
    pass a wider ``bits`` mask to also drop on e.g. ``ERR_CRC``) is
    treated as dead for this step's :func:`quorum_mean_grads`: its
    gradient may be built on partially-delivered halo/parameter data,
    so excluding it is the safe degradation.  Works traced (inside the
    step) or on host values.
    """
    failed = (error.astype(jnp.int32) & bits) != 0
    return live * jnp.where(failed, 0.0, 1.0).astype(live.dtype)


def reshard_state(state, shardings):
    """Elastic restart onto a different mesh: device_put every leaf to its
    new sharding (checkpoints store global arrays, so this is total)."""
    return jax.tree.map(jax.device_put, state, shardings,
                        is_leaf=lambda x: x is None)


class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail the step
    the first time each listed step number is reached."""

    def __init__(self, fail_at: set[int]):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")
