"""Pipeline parallelism on Shoal Medium AMs (GPipe-style, 2+ stages).

The paper's Medium AM is point-to-point payload delivery straight to a
kernel — exactly a pipeline stage handoff.  Stages map onto consecutive
ranks of a mesh axis (e.g. the ``pod`` axis: stage boundary = the DCN
link, the classic reason to pipeline across pods); microbatches stream
through a ``lax.scan`` whose per-tick communication is one
``lax.ppermute`` hop (the Medium AM's wire op).

Forward-only schedule with the standard GPipe bubble; autodiff through
the scan + ppermute gives the backward schedule for free (the transpose
of a ppermute is the reverse ppermute — the backward bubble mirrors the
forward one).

This is the minimal composable form: ``stage_fn(stage_params, x)`` is
any per-stage function with matching x shapes (e.g. a slice of a layer
stack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map


def pipeline_apply(mesh, axis: str, stage_fn, stage_params, mbs):
    """Run ``mbs`` (M, mb, ...) microbatches through n_stages stages.

    ``stage_params``: pytree whose leaves have a leading n_stages dim
    (stage i's slice lives on rank i of ``axis``).  Returns the stage
    outputs for every microbatch, (M, mb, ...), produced on the LAST
    rank and broadcast back (so the caller can compute a loss anywhere).
    """
    n = mesh.shape[axis]
    M = mbs.shape[0]
    perm = [(i, i + 1) for i in range(n - 1)]          # stage i -> i+1

    def per_device(params_slice, mbs_local):
        params_slice = jax.tree.map(lambda x: x[0], params_slice)
        me = lax.axis_index(axis)
        ticks = M + n - 1

        def tick(carry, t):
            # inject microbatch t at stage 0; everyone runs its stage on
            # whatever arrived last tick; hand off via the Medium-AM hop
            inbox = carry
            mb_idx = jnp.clip(t, 0, M - 1)
            my_in = jnp.where(me == 0, mbs_local[mb_idx], inbox)
            my_out = stage_fn(params_slice, my_in)
            handed = lax.ppermute(my_out, axis, perm)
            # the last stage's output this tick corresponds to
            # microbatch t - (n - 1); collect it
            done = my_out
            return handed, done

        _, outs = lax.scan(tick, jnp.zeros_like(mbs_local[0]),
                           jnp.arange(ticks))
        # outs: (ticks, mb, ...); valid last-stage outputs are ticks
        # n-1 .. M+n-2 on rank n-1.  Broadcast them to all ranks.
        valid = lax.dynamic_slice_in_dim(outs, n - 1, M, axis=0)
        from repro.core import collectives as coll
        out = coll.broadcast_from(valid, axis, n, root=n - 1)
        return out[None]

    fn = shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(axis),
        check_vma=False)
    out = fn(stage_params, mbs)
    # out: (n, M, mb, ...) — every rank holds the broadcast copy
    return out[0]


def split_stages(params_stacked, n_stages: int):
    """Split a layer-stacked param tree (L, ...) into (n_stages, L/n, ...)."""
    def one(x):
        L = x.shape[0]
        assert L % n_stages == 0
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(one, params_stacked)
