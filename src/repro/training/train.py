"""The trainer: train_step factories with selectable comm backend.

Two backends, both producing the same math (tested against each other):

* ``xla`` — the whole step is one jit-GSPMD program: batch sharded over
  the DP axes, weights per the model's PartitionSpecs, collectives
  inserted and fused/overlapped by the compiler.  The *beyond-paper*
  path and the hillclimb vehicle.
* ``shoal`` — the paper-faithful path: loss+grad run *manually* sharded
  over the DP axes (partial-manual shard_map, model axis left to
  GSPMD), and the DP gradient sync is an explicit Shoal ring
  all-reduce (:func:`repro.core.collectives.ring_all_reduce`) — i.e. the
  one-sided Long-put-with-ADD datapath.  Optional int8 error-feedback
  compression on the sync.  Requires replicated-over-DP params (no
  FSDP) — documented in DESIGN.md.

Also here: gradient accumulation (microbatching), straggler-quorum DP
(see :mod:`repro.training.elastic`), and metrics.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.core import collectives as coll
from repro.models.model import Model
from repro.optim import adamw as aw
from repro.optim import dist as od


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray
    ef_residual: Any = None       # int8 error-feedback buffers (or None)


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    comm_backend: str = "xla"       # xla | shoal
    microbatches: int = 1
    grad_compression: bool = False  # int8 EF on the DP sync (shoal backend)
    donate: bool = True


class Trainer:
    def __init__(self, model: Model, opt_cfg: aw.AdamWConfig,
                 tcfg: TrainerConfig = TrainerConfig(),
                 dp_axes: tuple[str, ...] | None = None):
        """``dp_axes`` defaults to the model's.  For the shoal backend the
        model should be built with ``dp_axes=()`` (its activation
        constraints must not mention the manual DP axes) and the real DP
        axes passed here."""
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = model.mesh
        self.dp_axes = dp_axes if dp_axes is not None else model.dp_axes

    # -- state ----------------------------------------------------------------

    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        opt = aw.adamw_init(params)
        ef = (od.make_error_feedback(params)
              if self.tcfg.grad_compression else None)
        return TrainState(params=params, opt_state=opt,
                          step=jnp.zeros((), jnp.int32), ef_residual=ef)

    def state_pspecs(self, state: TrainState):
        pp = self.model.param_pspecs(state.params)
        dp = self.dp_axes[-1]
        dp_size = self.mesh.shape[dp] if self.mesh is not None else 1
        opt_p = {
            "m": od.zero1_pspecs(pp, dp, state.params, dp_size),
            "v": od.zero1_pspecs(pp, dp, state.params, dp_size),
            "count": P(),
        }
        ef = None if state.ef_residual is None else jax.tree.map(
            lambda *_: P(), state.ef_residual)
        return TrainState(params=pp, opt_state=opt_p, step=P(),
                          ef_residual=ef)

    def state_shardings(self, state: TrainState):
        if self.mesh is None:
            return None
        specs = self.state_pspecs(state)
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))

    def batch_pspec(self) -> P:
        return P(self.dp_axes)

    def batch_shardings(self, batch):
        if self.mesh is None:
            return {k: None for k in batch}
        return {k: NamedSharding(self.mesh, P(self.dp_axes))
                for k in batch}

    # -- losses ----------------------------------------------------------------

    def _loss_microbatched(self, params, batch):
        n = self.tcfg.microbatches
        if n == 1:
            return self.model.loss(params, batch)

        def slice_mb(x, i):
            mb = x.shape[0] // n
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

        def body(acc, i):
            mb = {k: slice_mb(v, i) for k, v in batch.items()}
            return acc + self.model.loss(params, mb), None

        # checkpoint the microbatch body: otherwise the scan stacks every
        # microbatch's residuals and grad accumulation saves no memory
        body = jax.checkpoint(body)
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                jnp.arange(n))
        return total / n

    # -- xla backend -------------------------------------------------------------

    def make_train_step(self):
        if self.tcfg.comm_backend == "shoal":
            return self._make_train_step_shoal()
        return self._make_train_step_xla()

    def _apply_update(self, state: TrainState, grads, loss):
        new_params, new_opt, metrics = aw.adamw_update(
            self.opt_cfg, grads, state.opt_state, state.params)
        metrics["loss"] = loss
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1,
                               ef_residual=state.ef_residual)
        return new_state, metrics

    def _make_train_step_xla(self):
        def step(state: TrainState, batch):
            loss, grads = jax.value_and_grad(self._loss_microbatched)(
                state.params, batch)
            return self._apply_update(state, grads, loss)

        donate = (0,) if self.tcfg.donate else ()
        return jax.jit(step, donate_argnums=donate)

    # -- shoal backend --------------------------------------------------------------

    def _make_train_step_shoal(self):
        """Manual-DP: per-device grads on the local batch shard, then an
        explicit Shoal ring all-reduce (optionally int8-EF-compressed)."""
        mesh = self.mesh
        assert mesh is not None, "shoal backend needs a mesh"
        assert not self.model.cfg.fsdp, (
            "shoal DP backend needs replicated-over-DP params (no FSDP); "
            "see DESIGN.md Sec. 4")
        dp = self.dp_axes
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]

        def grads_fn(params, batch):
            loss, grads = jax.value_and_grad(self._loss_microbatched)(
                params, batch)
            return loss, grads

        def sync(avg_or_tree):
            """ring all-reduce each grad leaf over the flattened DP axes."""
            def one(g):
                red = coll.ring_all_reduce(g.astype(jnp.float32), dp, n_dp)
                return (red / n_dp).astype(g.dtype)
            return jax.tree.map(one, avg_or_tree)

        def sync_compressed(grads, residual):
            qtree, new_res = od.ef_compress_tree(grads, residual)

            def one(qs):
                q, s = qs
                # sum int8 payloads in int32 (4x fewer wire bytes than f32
                # on the pod/DP axis), scales reduced alongside
                red = coll.ring_all_reduce(q.astype(jnp.int32), dp, n_dp)
                smax = coll.ring_all_reduce(s[None], dp, n_dp)[0] / n_dp
                return (red.astype(jnp.float32) * smax / n_dp)

            synced = jax.tree.map(one, qtree,
                                  is_leaf=lambda x: isinstance(x, tuple))
            return synced, new_res

        def local_step(state: TrainState, batch):
            loss, grads = grads_fn(state.params, batch)
            loss = jax.lax.pmean(loss, dp)
            if self.tcfg.grad_compression:
                synced, new_res = sync_compressed(grads, state.ef_residual)
                state = TrainState(params=state.params,
                                   opt_state=state.opt_state,
                                   step=state.step, ef_residual=new_res)
            else:
                synced = sync(grads)
            return self._apply_update(state, synced, loss)

        def spmd_step(state, batch):
            # partial-manual: DP axes manual (explicit shoal ring); the
            # model axis stays GSPMD-auto.  P() / P(dp) are prefix specs
            # broadcast over the pytrees.
            batch_specs = {k: P(dp) for k in batch}
            fn = shard_map(
                local_step, mesh=mesh,
                in_specs=(P(), batch_specs),
                out_specs=(P(), P()),
                axis_names=set(dp), check_vma=False)
            return fn(state, batch)

        donate = (0,) if self.tcfg.donate else ()
        return jax.jit(spmd_step, donate_argnums=donate)
