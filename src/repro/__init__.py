"""Shoal-JAX: a PGAS Active-Message substrate + LM training/serving
framework for TPU pods.

Reproduction and pod-scale extension of "A PGAS Communication Library
for Heterogeneous Clusters" (Sharma & Chow, 2021).  See DESIGN.md for
the FPGA->TPU adaptation and EXPERIMENTS.md for the dry-run, roofline,
and perf-iteration results.

Subpackages:
  core       the Shoal library (AMs, GAScore, ops, collectives, HUMboldt)
  runtime    Galapagos analogue (topology, transports, routing)
  models     the 10 assigned architectures
  data/optim/checkpoint/training/serving   framework substrates
  kernels    Pallas TPU kernels + oracles (incl. the RDMA GAScore)
  apps       the paper's Jacobi application
  configs    exact assigned configs + reduced smoke configs
  launch     production mesh, 512-chip dry-run, train/serve drivers
"""
