"""deepseek-7b [dense]: 30L d_model=4096 32H (kv=32, MHA) d_ff=11008
vocab=102400 (llama-arch) [arXiv:2401.02954; hf]."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab=512, dtype=jnp.float32)
