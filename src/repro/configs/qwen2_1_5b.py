"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias, tied embeddings [arXiv:2407.10671; hf]."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936,
        qkv_bias=True, tie_embeddings=True, rope_base=1e6, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=256, vocab=512,
        qkv_bias=True, tie_embeddings=True, dtype=jnp.float32)
