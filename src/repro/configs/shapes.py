"""Assigned input shapes.  ``train_*`` lowers train_step; ``prefill_*``
lowers the prompt pass; ``decode_*`` / ``long_*`` lower serve_step (one
new token against a seq_len-deep cache).  ``long_500k`` applies only to
sub-quadratic archs (cfg.sub_quadratic), per the assignment."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.sub_quadratic
    return True
