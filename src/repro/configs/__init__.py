"""Assigned architecture configs (exact, from public literature) plus
reduced same-family smoke configs.  ``get(name)`` returns the module;
each module exposes ``full()`` and ``reduced()`` -> ModelConfig.
"""

import importlib

ARCH_IDS = [
    "dbrx_132b",
    "deepseek_v2_236b",
    "qwen2_1_5b",
    "tinyllama_1_1b",
    "deepseek_7b",
    "qwen2_72b",
    "musicgen_medium",
    "llama_3_2_vision_90b",
    "recurrentgemma_2b",
    "xlstm_350m",
]

# CLI ids (hyphenated, as assigned) -> module names
CLI_IDS = {i.replace("_", "-"): i for i in ARCH_IDS}
CLI_IDS.update({
    "qwen2-1.5b": "qwen2_1_5b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
})


def get(name: str):
    mod = CLI_IDS.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}")


def full(name: str):
    return get(name).full()


def reduced(name: str):
    return get(name).reduced()
