"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""

import jax.numpy as jnp

from repro.models.model import ModelConfig
from repro.models.moe import MoEDims


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=10752, vocab=100352,
        moe=MoEDims(n_experts=16, top_k=4, d_ff_expert=10752),
        fsdp=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=192, vocab=512,
        moe=MoEDims(n_experts=8, top_k=4, d_ff_expert=96),
        dtype=jnp.float32)
