"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern 1 attn : 2 recurrent
[arXiv:2402.19427; hf].  Sub-quadratic: runs long_500k (RG-LRU state +
2048-token local-attention window)."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=26,
        d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000,
        d_head=256, window=2048, lru_width=2560,
        block_pattern=("rglru", "rglru", "attn_local"),
        sub_quadratic=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", n_layers=5,
        d_model=80, n_heads=5, n_kv_heads=1, d_ff=160, vocab=512,
        d_head=32, window=16, lru_width=80,
        block_pattern=("rglru", "rglru", "attn_local"),
        sub_quadratic=True, dtype=jnp.float32)
