"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 (llama2-arch small) [arXiv:2401.02385; hf]."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab=512, dtype=jnp.float32)
