"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].  Dense first layer (first_k_dense=1) uses the
model's dense intermediate 12288; d_ff_expert=1536 per assignment."""

import jax.numpy as jnp

from repro.models.attention import MLADims
from repro.models.model import ModelConfig
from repro.models.moe import MoEDims


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=12288, vocab=102400, d_head=128,
        moe=MoEDims(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        mla=MLADims(q_lora=1536, kv_lora=512, dh_nope=128, dh_rope=64,
                    dh_v=128),
        first_k_dense=1, fsdp=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke", family="moe", n_layers=3, d_model=96,
        n_heads=6, n_kv_heads=6, d_ff=192, vocab=512, d_head=16,
        moe=MoEDims(n_experts=8, top_k=3, d_ff_expert=48, n_shared=2),
        mla=MLADims(q_lora=48, kv_lora=24, dh_nope=16, dh_rope=8, dh_v=16),
        first_k_dense=1, dtype=jnp.float32)
