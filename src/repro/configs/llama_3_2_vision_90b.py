"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256, cross-attn image layers (every 5th)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  The vision tower is
a STUB: input_specs provide precomputed patch embeddings (B, 1600, d)."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm", n_layers=100,
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
        cross_every=5, n_image_tokens=1600, rope_base=5e5,
        fsdp=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm", n_layers=5, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=192, vocab=512,
        cross_every=5, n_image_tokens=16, dtype=jnp.float32)
