"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
The EnCodec frontend is a STUB: input_specs provide precomputed frame
embeddings (B, S, d); the head predicts one codebook stream (vocab 2048).
LayerNorm + GELU MLP per the MusicGen transformer."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
        norm="ln", mlp="gelu", frontend="embeddings", remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        norm="ln", mlp="gelu", frontend="embeddings", dtype=jnp.float32)
