"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias [arXiv:2407.10671; hf]."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
        qkv_bias=True, rope_base=1e6, fsdp=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke", family="dense", n_layers=3, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=320, vocab=512,
        qkv_bias=True, dtype=jnp.float32)
