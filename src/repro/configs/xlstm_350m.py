"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks [arXiv:2405.04517; unverified].  xLSTM[7:1] ratio: every
8th block is sLSTM.  d_ff=0: the FFN lives inside the blocks (mLSTM
up/down pf=2; sLSTM tail MLP pf=4/3).  Sub-quadratic: runs long_500k."""

import jax.numpy as jnp

from repro.models.model import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        slstm_every=8, mlstm_chunk=256, sub_quadratic=True, remat="dots")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=512,
        slstm_every=8, mlstm_chunk=16, sub_quadratic=True,
        dtype=jnp.float32)
