"""Pass-1 driver: trace a program, record its comm schedule, lint it.

``lint(fn, *args)`` re-traces ``fn`` under a fresh event recorder
(:mod:`repro.analysis.trace`), runs the R1-R4 rules over the recorded
schedule, and cross-checks that the events are recoverable from the
closed jaxpr's ``shoal.*`` named scopes — the post-trace tagging the
whole analyzer hangs off.

jit-cache hazard: ``jax.make_jaxpr`` on an already-jitted callable can
hit the pjit trace cache and *skip the Python body*, so no events would
be recorded even though the jaxpr is full of comm ops.  We unwrap
``__wrapped__`` (``jax.jit`` preserves it) down to the raw traceable and
treat "tags in the jaxpr but zero events recorded" as an infrastructure
error rather than a clean report.
"""

from __future__ import annotations

import time

import jax

from repro.analysis import rules, trace
from repro.analysis.report import CommLintError, Report


def _unwrap(fn):
    """Strip ``jax.jit`` layers only.

    The pjit wrapper is the one with a trace cache; a ``shard_map``
    wrapper also carries ``__wrapped__`` but must stay in place — its
    body binds the mesh axes (``axis_index`` inside would be unbound).
    """
    seen: set[int] = set()
    while isinstance(fn, jax.stages.Wrapped) \
            and hasattr(fn, "__wrapped__") and id(fn) not in seen:
        seen.add(id(fn))
        fn = fn.__wrapped__
    return fn


def lint(fn, *args, name: str | None = None) -> Report:
    """Trace ``fn(*args)``, record its comm schedule, run rules R1-R4.

    ``fn`` may be jitted and/or shard_mapped; it is unwrapped to the raw
    traceable first so the Python body (and its ``emit`` calls) actually
    runs.  Returns a :class:`Report`; raising on findings is
    :func:`lint_clean`'s job.
    """
    target = _unwrap(fn)
    if name is None:
        name = getattr(target, "__name__", None) or repr(fn)
    t0 = time.perf_counter()
    with trace.record() as rec:
        closed = jax.make_jaxpr(target)(*args)
    tags = trace.recover_tags(closed)
    if tags and not rec.events:
        raise RuntimeError(
            f"shoal-lint {name}: the jaxpr carries {len(tags)} shoal.* "
            "tag(s) but tracing recorded no events — a trace cache "
            "served the jaxpr without running the Python body. Lint the "
            "unjitted callable (or a fresh closure) instead.")
    rep = Report(entry=name, n_events=len(rec.events),
                 tags_recovered=len(tags))
    rep.extend(rules.analyze(rec.events))
    rep.wall_time_s = time.perf_counter() - t0
    return rep


def lint_events(events, name: str = "<schedule>") -> Report:
    """Lint an explicit event schedule (no tracing) — the entry point
    for synthetic/fuzzed schedules in tests."""
    rep = Report(entry=name, n_events=len(events))
    return rep.extend(rules.analyze(list(events)))


def lint_clean(fn, *args, name: str | None = None) -> Report:
    """Assert ``fn`` has no unwaived findings; the pytest-facing form.

    Raises :class:`CommLintError` (an AssertionError) rendering every
    finding when the program is not clean; returns the report otherwise.
    """
    rep = lint(fn, *args, name=name)
    if not rep.ok:
        raise CommLintError(rep)
    return rep
