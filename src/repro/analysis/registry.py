"""Registry of lintable entry points — the programs CI guards.

Each entry builds (lazily; the imports are heavy) one representative
compiled program of a subsystem and exposes it to both analyzer passes:
the traceable ``fn(*args)`` for the jaxpr lint and an ``hlo()`` thunk
yielding optimized HLO text for the budget diff.  ``run_entry`` is the
single path the CLI, CI, tests, and benchmarks all share, so "zero
findings on shipped entry points" means the same thing everywhere.

The host platform must be forced to enough devices *before* jax import
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the CLI does
this itself, subprocess tests inherit it from conftest.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.analysis import hlo_budget, jaxpr_lint
from repro.analysis.report import Report


@dataclasses.dataclass(frozen=True)
class Entry:
    name: str
    description: str
    devices: int                  # host devices the program needs
    build: Callable[[], tuple]    # -> (fn, args, hlo_thunk)


def _tiny_tcp():
    import dataclasses as dc

    from repro.runtime import TCP
    return dc.replace(TCP, max_packet_bytes=64)


def _build_jacobi():
    """Jacobi halo exchange, 64x64 on 8 kernels, segmenting halos."""
    import jax.numpy as jnp

    from repro.apps.jacobi import JacobiApp
    from repro.core.address_space import GlobalAddressSpace

    app = JacobiApp(n=64, kernels=8, iters=1, transport=_tiny_tcp())
    gas = GlobalAddressSpace(app.ctx)
    st = gas.make_global_state()
    blocks = jnp.zeros((8, 64 // 8, 64), jnp.float32)
    fn = app.build()
    return fn, (st, blocks), lambda: fn.lower(st, blocks).compile().as_text()


def _build_jacobi_steady():
    """Jacobi steady state: 4 piggybacked iterations in one scan.

    The budget divides out the trip count: 2 collective-permutes per
    iteration (one per halo direction, no ack collectives — acks ride
    the next iteration's reverse-link packet) plus the 2 loop-exit
    ledger drains.
    """
    import jax.numpy as jnp

    from repro.apps.jacobi import JacobiApp
    from repro.core.address_space import GlobalAddressSpace

    app = JacobiApp(n=64, kernels=8, iters=4, transport=_tiny_tcp(),
                    piggyback=True)
    gas = GlobalAddressSpace(app.ctx)
    st = gas.make_global_state()
    blocks = jnp.zeros((8, 64 // 8, 64), jnp.float32)
    fn = app.build()
    return fn, (st, blocks), lambda: fn.lower(st, blocks).compile().as_text()


def _build_actors_mailbox():
    """The actor-layer headline: 1024 4-word sends -> one flush."""
    import jax
    import numpy as np

    from repro.core import ops
    from repro.core.address_space import GlobalAddressSpace
    from repro.core.state import ShoalContext
    from repro.runtime import TCP
    from repro.runtime.topology import make_cpu_mesh

    n_msgs, w, n = 1024, 4, 8
    ring = [(i, (i + 1) % n) for i in range(n)]
    ctx = ShoalContext(mesh=make_cpu_mesh(n, ("kernel",)), axes=("kernel",),
                       transport=TCP, segment_words=n_msgs * w + 64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        mb = ctx.mailbox(ring, msg_words=w, watermark=1 << 20, token=1)
        base = np.arange(w, dtype=np.float32)
        for i in range(n_msgs):
            st = mb.send(st, base + i, dst_addr=w * i)
        st = mb.flush(st)
        return ops.wait_replies(ctx, st, token=1, n=1)

    fn = gas.spmd(prog)
    st0 = gas.make_global_state()
    jfn = jax.jit(fn)
    return fn, (st0,), lambda: jfn.lower(st0).compile().as_text()


def _build_moe_dispatch():
    """MoE all-to-all expert dispatch (a2a islands, mesh (2, 4))."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.model import ModelConfig, build_model
    from repro.models.moe import MoEDims
    from repro.runtime.jax_compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    dims = MoEDims(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                   capacity_factor=16.0, dispatch="a2a")
    cfg = ModelConfig(name="lint-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
                      fsdp=True, seq_shard=True, aux_loss_weight=0.0,
                      moe=dims, dtype=jnp.float32)
    model = build_model(cfg, mesh=mesh, dp_axes=("data",))
    params = build_model(dc.replace(cfg, fsdp=False, seq_shard=False)).init(
        jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (4, 32)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    jfn = jax.jit(model.loss)
    return (model.loss, (params, batch),
            lambda: jfn.lower(params, batch).compile().as_text())


def _build_lossy_put():
    """Reliable put over a 1%-drop DCN link: 4-seg acked put + wait.

    The compiled program unrolls 1 + max_retries attempt rounds, each a
    data exchange plus an ack exchange — 2*(1+max_retries) CPs — but
    rounds after delivery ship all-NOP packets, so the *dynamic* cost is
    tracked by the ``retransmits`` state counter, not the CP count.
    """
    import jax

    from repro.core import ops
    from repro.core.address_space import GlobalAddressSpace
    from repro.core.faults import FaultModel
    from repro.core.state import ShoalContext
    from repro.runtime import LossyTransport
    from repro.runtime.topology import make_cpu_mesh

    import jax.numpy as jnp

    n = 8
    ring = [(i, (i + 1) % n) for i in range(n)]
    transport = LossyTransport(faults=FaultModel(drop=0.01, seed=7),
                               max_packet_bytes=16, max_retries=4)
    ctx = ShoalContext(mesh=make_cpu_mesh(n, ("kernel",)), axes=("kernel",),
                       transport=transport, segment_words=64)
    gas = GlobalAddressSpace(ctx)

    def prog(st):
        me = ctx.my_id()
        pay = (jnp.arange(16, dtype=jnp.float32) + 1) * (me + 1)
        st = ops.put_long(ctx, st, pay, ring, dst_addr=10, token=1)
        return ops.wait_replies(ctx, st, token=1, n=1)

    fn = gas.spmd(prog)
    st0 = gas.make_global_state()
    jfn = jax.jit(fn)
    return fn, (st0,), lambda: jfn.lower(st0).compile().as_text()


def _build_kv_migrate():
    """Disaggregated-serving KV migration (one vectored put + reply)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import ServingSlices
    from repro.models.model import ModelConfig, build_model
    from repro.serving.disagg import DisaggServeTier
    from repro.serving.engine import lane_slice

    cfg = ModelConfig(name="lint-kv", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tier = DisaggServeTier(model, params, ServingSlices(n_prefill=2,
                                                       n_decode=2),
                           lanes_per_decode=2, slots=16)
    blocks = tuple(tier.kv.pack_lane(
        lane_slice(tier.workers[0]._cache0, 0)))
    fn = tier._migration(0, 2, 0)
    st = tier.state
    return fn, (st, blocks), lambda: fn.lower(st, blocks).compile().as_text()


ENTRIES: tuple[Entry, ...] = (
    Entry("jacobi", "Jacobi halo exchange (64x64, 8 kernels, 16-word MTU)",
          8, _build_jacobi),
    Entry("jacobi-steady",
          "Jacobi steady state: 4 piggybacked iterations, <=2 CPs/iter",
          8, _build_jacobi_steady),
    Entry("actors-mailbox", "1024 4-word mailbox sends, one flush + wait",
          8, _build_actors_mailbox),
    Entry("moe-dispatch", "MoE a2a expert dispatch, mesh (2,4), 2 layers",
          8, _build_moe_dispatch),
    Entry("kv-migrate", "serving KV migration, prefill 0 -> decode 2",
          4, _build_kv_migrate),
    Entry("lossy-put",
          "reliable 4-seg put over 1%-drop DCN, retransmit + dedup",
          8, _build_lossy_put),
)


def names() -> list[str]:
    return [e.name for e in ENTRIES]


def get(name: str) -> Entry:
    for e in ENTRIES:
        if e.name == name:
            return e
    raise KeyError(f"unknown lint entry {name!r}; known: {names()}")


def run_entry(name: str, budgets: dict | None = None, *,
              include_hlo: bool = True) -> Report:
    """Run both analyzer passes over one registered entry point."""
    import jax

    e = get(name)
    if len(jax.devices()) < e.devices:
        raise RuntimeError(
            f"lint entry {name!r} needs {e.devices} host devices; run "
            "with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{e.devices} (set before jax import)")
    t0 = time.perf_counter()
    fn, args, hlo_thunk = e.build()
    rep = jaxpr_lint.lint(fn, *args, name=name)
    if include_hlo:
        spec = (hlo_budget.load_budgets() if budgets is None
                else budgets).get(name)
        stats = hlo_budget.measure(hlo_thunk())
        rep.extend(hlo_budget.check_budget(name, stats, spec))
        rep.budget = hlo_budget.budget_row(stats, spec)
    rep.wall_time_s = time.perf_counter() - t0
    return rep
