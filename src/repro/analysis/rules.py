"""Comm-safety rules R1-R5 over a recorded event schedule.

The analyzer input is the ordered list of :class:`~repro.analysis.trace.
CommEvent`s one Python trace of the program produced (SPMD dataflow: the
trace IS the schedule; a ``lax.scan`` body contributes one loop
instance).  The rules model the *paper's* asynchronous PGAS semantics —
an AM is in flight from issue until an ordering point covers it — not
the lockstep emulation, so hazards that today's collectivized lowering
happens to serialize are still reported: they become real the moment the
transport is an actual NIC.

Ordering model (happens-before at the destination):

* ``barrier`` orders every earlier event before every later one;
* ``wait_replies(token=t)`` orders every earlier *acked* event on
  ``t`` before every later event (for acks deferred through a
  ReplyMailbox, only once a credit-grant for ``t`` has also been
  issued);
* asynchronous events are only ever ordered by a barrier.

Traced operands degrade conservatively: an unknown interval may alias
anything, an unknown token makes every token's balance unknown.
"""

from __future__ import annotations

from repro.analysis.report import ERROR, WARNING, Finding
from repro.analysis.trace import (CommEvent, Interval, READ_OPS, WRITE_OPS)


def _waiver_of(*events: CommEvent) -> str | None:
    for ev in events:
        if ev.waiver:
            return ev.waiver
    return None


def _common_dsts(a: CommEvent, b: CommEvent) -> tuple[int, ...]:
    return tuple(sorted(set(a.dsts) & set(b.dsts)))


def _grant_indices(events, token: int | None):
    """Indices of events that grant credits on ``token``: explicit
    Short-AM grants, a packet whose piggyback lane carries that token's
    deferred acks home, or a ledger drain."""
    out = []
    for k, ev in enumerate(events):
        if any(t == token for t, _ in ev.credit_grants) \
                or (token is not None and ev.piggyback_token == token) \
                or (ev.drains_deferred and ev.token == token):
            out.append(k)
    return out


def _ordered_before(events, i: int, j: int) -> bool:
    """True when an ordering point between events i and j covers event i."""
    ei = events[i]
    for k in range(i + 1, j):
        ev = events[k]
        if ev.op == "barrier":
            return True
        if ev.op == "wait_replies" and ei.acked and ei.token is not None \
                and ev.token == ei.token:
            # acks deferred through a ReplyMailbox or a receiver-side
            # piggyback ledger only order once a grant event (flush,
            # piggyback lane, or drain) sits between the op and the wait
            if not (ei.deferred_reply or ei.defer_ack):
                return True
            if any(i < g < k for g in _grant_indices(events, ei.token)):
                return True
    return False


def _overlapping(a: CommEvent, b: CommEvent):
    """First overlapping (interval, interval) pair, or None."""
    for wa in a.writes:
        for wb in b.writes:
            if wa.overlaps(wb):
                return wa, wb
    return None


# --------------------------------------------------------------------------
# R1: write-write overlap without ordering
# --------------------------------------------------------------------------

def check_r1(events) -> list[Finding]:
    out: list[Finding] = []
    writes = [(i, ev) for i, ev in enumerate(events) if ev.op in WRITE_OPS]
    for a in range(len(writes)):
        i, ei = writes[a]
        # intra-op hazard: the pre-PR6 strided class (vectorized ingress
        # over aliasing blocks scatters in undefined lane order)
        if ei.self_overlap and ei.op == "put_long_strided":
            out.append(Finding(
                rule="R1", severity=ERROR, events=(ei.seq,),
                sites=(ei.site(),), waived=ei.waiver,
                message=(f"strided put {ei.site()} has aliasing blocks "
                         f"(stride {ei.detail.get('stride')} < blk_words "
                         f"{ei.detail.get('blk_words')}) on the unordered "
                         "vectorized ingress: scatter lane order is "
                         "undefined, so last-writer-wins and accumulate "
                         "handlers are both wrong (pass overlap=True or "
                         "drop the override)")))
        for b in range(a + 1, len(writes)):
            j, ej = writes[b]
            common = _common_dsts(ei, ej)
            if not common:
                continue
            pair = _overlapping(ei, ej)
            if pair is None:
                continue
            if _ordered_before(events, i, j):
                continue
            wa, wb = pair
            out.append(Finding(
                rule="R1", severity=ERROR, events=(ei.seq, ej.seq),
                sites=(ei.site(), ej.site()), waived=_waiver_of(ei, ej),
                message=(f"{ei.site()} writes {wa} and {ej.site()} writes "
                         f"{wb} at kernel(s) {list(common)} with no "
                         "ordering (wait_replies on the first op's token, "
                         "or a barrier) between them — destination value "
                         "depends on arrival order")))
    return out


# --------------------------------------------------------------------------
# R2: read overlapping an in-flight write
# --------------------------------------------------------------------------

def check_r2(events) -> list[Finding]:
    out: list[Finding] = []
    for j, ej in enumerate(events):
        if ej.op not in READ_OPS or not ej.reads:
            continue
        for i in range(j):
            ei = events[i]
            if ei.op not in WRITE_OPS or not ei.writes:
                continue
            common = _common_dsts(ei, ej)
            if not common:
                continue
            hit = None
            for r in ej.reads:
                for w in ei.writes:
                    if r.overlaps(w):
                        hit = (w, r)
                        break
                if hit:
                    break
            if hit is None or _ordered_before(events, i, j):
                continue
            w, r = hit
            out.append(Finding(
                rule="R2", severity=ERROR, events=(ei.seq, ej.seq),
                sites=(ei.site(), ej.site()), waived=_waiver_of(ei, ej),
                message=(f"{ej.site()} reads {r} at kernel(s) "
                         f"{list(common)} while {ei.site()}'s write to {w} "
                         "is still in flight (no wait_replies on token "
                         f"{ei.token!r}, no barrier): the get may return "
                         "pre- or post-write data")))
    return out


# --------------------------------------------------------------------------
# R3: credit flow (underflow / leak / double-spend)
# --------------------------------------------------------------------------

def check_r3(events) -> list[Finding]:
    out: list[Finding] = []
    balance: dict[int, int] = {}
    known: dict[int, bool] = {}
    contributors: dict[int, list[CommEvent]] = {}
    mailboxes: dict[int, set[int]] = {}
    # receiver-side piggyback ledger: acks a defer_ack put owes, pending
    # a reverse-link packet (piggyback_token) or an explicit drain
    deferred: dict[int, int] = {}
    deferred_evs: dict[int, list[CommEvent]] = {}
    all_unknown = False

    def bump(token, n, ev):
        if token is None:
            return
        balance[token] = balance.get(token, 0) + n
        contributors.setdefault(token, []).append(ev)

    for ev in events:
        if ev.op == "wait_replies":
            if ev.token is None:
                all_unknown = True      # traced token: drains *some* token
                continue
            t = ev.token
            if ev.wait_n is None:
                known[t] = False
                contributors.pop(t, None)
                mailboxes.pop(t, None)
                continue
            if ev.timeout:
                # timeout wait: drains min(have, n) and latches nothing —
                # a shortfall is the *expected* outcome under loss, so no
                # underflow finding; the balance cannot go negative
                balance[t] = max(balance.get(t, 0) - ev.wait_n, 0)
                contributors.pop(t, None)
                mailboxes.pop(t, None)
                continue
            if not all_unknown and known.get(t, True) \
                    and ev.wait_n > balance.get(t, 0):
                issued = balance.get(t, 0)
                out.append(Finding(
                    rule="R3", severity=ERROR, events=(ev.seq,),
                    sites=(ev.site(),), waived=ev.waiver,
                    message=(f"{ev.site()} waits for {ev.wait_n} replies "
                             f"on token {t} but the schedule issues only "
                             f"{issued} acked credit(s) — this is the "
                             "trace-time form of ERR_WAIT_UNDERFLOW (a "
                             "hang in the threaded original)")))
            balance[t] = balance.get(t, 0) - ev.wait_n
            contributors.pop(t, None)
            mailboxes.pop(t, None)
            continue
        if ev.token is None and (ev.acked or ev.credit_grants
                                 or ev.drains_deferred):
            all_unknown = True
            continue
        # the piggyback lane is loaded from the ledger as of SEND time,
        # so it moves acks pooled by *earlier* events (including this
        # call's own defer, which lands at the receiver only afterwards)
        if ev.piggyback_token is not None:
            moved = deferred.pop(ev.piggyback_token, 0)
            if moved:
                bump(ev.piggyback_token, moved, ev)
            deferred_evs.pop(ev.piggyback_token, None)
        if ev.drains_deferred:
            moved = deferred.pop(ev.token, 0)
            if moved:
                bump(ev.token, moved, ev)
            deferred_evs.pop(ev.token, None)
            continue
        if ev.acked and not ev.deferred_reply:
            if ev.defer_ack:
                deferred[ev.token] = deferred.get(ev.token, 0) + 1
                deferred_evs.setdefault(ev.token, []).append(ev)
            else:
                bump(ev.token, 1, ev)
        for t, n in ev.credit_grants:
            bump(t, n, ev)
            contributors.setdefault(t, [])
        if ev.mailbox_id is not None and ev.acked and ev.token is not None:
            seen = mailboxes.setdefault(ev.token, set())
            seen.add(ev.mailbox_id)
            if len(seen) > 1:
                out.append(Finding(
                    rule="R3", severity=WARNING, events=(ev.seq,),
                    sites=(ev.site(),), waived=ev.waiver,
                    message=(f"token {ev.token} collects flush acks from "
                             f"{len(seen)} distinct mailboxes with no "
                             "wait_replies between flushes — a "
                             "double-spend hazard: wait counts can no "
                             "longer be attributed per mailbox")))
    if not all_unknown:
        for t, bal in sorted(balance.items()):
            if bal > 0 and known.get(t, True):
                evs = contributors.get(t, [])
                out.append(Finding(
                    rule="R3", severity=WARNING,
                    events=tuple(e.seq for e in evs),
                    sites=tuple(e.site() for e in evs),
                    waived=_waiver_of(*evs) if evs else None,
                    message=(f"{bal} credit(s) on token {t} are never "
                             "consumed by a wait_replies — leaked acks "
                             "(flush/put without credit consumption) "
                             "accumulate across phases and corrupt later "
                             "wait counts")))
        for t, cnt in sorted(deferred.items()):
            if cnt > 0 and known.get(t, True):
                evs = deferred_evs.get(t, [])
                out.append(Finding(
                    rule="R3", severity=WARNING,
                    events=tuple(e.seq for e in evs),
                    sites=tuple(e.site() for e in evs),
                    waived=_waiver_of(*evs) if evs else None,
                    message=(f"{cnt} deferred ack(s) on token {t} are "
                             "stranded in the receiver ledger: no later "
                             "reverse-link packet piggybacks them "
                             "(piggyback_token) and no drain_deferred_acks "
                             "ships them, so the sender's wait_replies on "
                             f"token {t} can never be satisfied")))
    return out


# --------------------------------------------------------------------------
# R4: out-of-bounds and vectored aliasing
# --------------------------------------------------------------------------

def _oob(iv: Interval, segment_words: int) -> bool:
    return iv.known and (iv.start < 0 or iv.start + iv.words > segment_words)


def check_r4(events) -> list[Finding]:
    out: list[Finding] = []
    for ev in events:
        if not ev.segment_words:
            continue
        for kind, ivs in (("write", ev.writes), ("read", ev.reads)):
            for iv in ivs:
                if _oob(iv, ev.segment_words):
                    out.append(Finding(
                        rule="R4", severity=ERROR, events=(ev.seq,),
                        sites=(ev.site(),), waived=ev.waiver,
                        message=(f"{ev.site()} {kind}s {iv} outside the "
                                 f"{ev.segment_words}-word segment: the "
                                 "GAScore clips out-of-range addresses "
                                 "silently, so part of the message is "
                                 "dropped (or lands at the clip boundary)")))
        if ev.op == "put_long_vectored" and ev.self_overlap:
            alias = ev.detail.get("alias", "duplicate/overlapping addresses")
            out.append(Finding(
                rule="R4", severity=ERROR, events=(ev.seq,),
                sites=(ev.site(),), waived=ev.waiver,
                message=(f"vectored put {ev.site()} has aliasing "
                         f"destination blocks in one packet ({alias}): "
                         "the receiver's scatter makes the result depend "
                         "on block order")))
    return out


# --------------------------------------------------------------------------
# R5: loss-resilience protocol hygiene on lossy transports
# --------------------------------------------------------------------------

def check_r5(events) -> list[Finding]:
    """Lossy-link delivery semantics.

    A retransmitting put whose receiver does not dedup redelivery is an
    ERROR: a duplicated or re-sent segment is applied twice, which
    corrupts accumulate handlers (H_ADD) and re-runs any non-idempotent
    handler.  An acked put with no retry budget, or a fire-and-forget
    put, on a lossy link is a WARNING — losses surface as
    ERR_RETRY_EXHAUSTED / silent data loss respectively, which may be a
    deliberate degradation policy but deserves a waiver saying so.
    """
    out: list[Finding] = []
    for ev in events:
        if not ev.lossy or ev.op not in WRITE_OPS:
            continue
        if ev.retries > 0 and not ev.dedup:
            out.append(Finding(
                rule="R5", severity=ERROR, events=(ev.seq,),
                sites=(ev.site(),), waived=ev.waiver,
                message=(f"{ev.site()} retransmits (up to {ev.retries}x) "
                         "over a lossy link with dedup=False: a lost ack "
                         "re-delivers segments the receiver already "
                         "applied, so handlers run twice (double-applied "
                         "H_ADD, re-run side effects) — enable the dedup "
                         "ledger or drop the retry budget")))
        elif ev.acked and ev.retries == 0:
            out.append(Finding(
                rule="R5", severity=WARNING, events=(ev.seq,),
                sites=(ev.site(),), waived=ev.waiver,
                message=(f"{ev.site()} is acked over a lossy link with no "
                         "retransmit budget (max_retries=0): any single "
                         "drop latches ERR_RETRY_EXHAUSTED immediately")))
        elif not ev.acked:
            out.append(Finding(
                rule="R5", severity=WARNING, events=(ev.seq,),
                sites=(ev.site(),), waived=ev.waiver,
                message=(f"{ev.site()} is fire-and-forget over a lossy "
                         "link: drops and corruptions are silent data "
                         "loss (no ack, no retransmit) — acceptable only "
                         "if the application tolerates holes")))
    return out


def analyze(events) -> list[Finding]:
    """Run all pass-1 rules over a recorded schedule."""
    findings: list[Finding] = []
    findings.extend(check_r1(events))
    findings.extend(check_r2(events))
    findings.extend(check_r3(events))
    findings.extend(check_r4(events))
    findings.extend(check_r5(events))
    return findings
