"""Trace-time comm-event recording (pass 1 of shoal-lint).

Every Shoal op call site (:mod:`repro.core.ops`, the actor mailboxes in
:mod:`repro.actors`) reports one :class:`CommEvent` here while a
recorder is active, carrying the *static* operands the analyzer needs:
per-destination address intervals, tokens, ack semantics, segmentation.
Because Shoal programs are SPMD dataflow, the Python trace of the
program IS its communication schedule — recording during ``make_jaxpr``
sees exactly the ops the compiled program will issue (a ``lax.scan``
body is traced once, so the recorded schedule is one loop instance).

Each event also tags its op's equations in the jaxpr/HLO via
``jax.named_scope`` with a ``shoal.<op>#e<seq>`` scope, so call sites
are recoverable *post-trace*: :func:`recover_tags` walks a closed
jaxpr's equations and maps them back to events by tag.  The same tags
show up as ``op_name`` metadata in compiled HLO, which is how a budget
finding in pass 2 can name the op that emitted the collective.

Traced (non-concrete) operands degrade conservatively: an interval
whose start is unknown is recorded with ``start=None`` and treated by
the rules as potentially overlapping everything in its segment.

Deliberate hazards are annotated inline with :func:`waiver`::

    with analysis.waiver("double-write is idempotent here"):
        state = ops.put_long(ctx, state, pay, pattern, dst_addr=0)

Events emitted under a waiver still produce findings, but the findings
are marked waived and do not fail ``lint_clean`` / the CLI.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator

import jax

# ops that write destination segment memory
WRITE_OPS = ("put_long", "put_long_strided", "put_long_vectored",
             "put_long_multi", "mailbox_flush")
# ops that read remote segment memory
READ_OPS = ("get_medium", "get_long")
# ordering / bookkeeping ops
SYNC_OPS = ("wait_replies", "barrier")


@dataclasses.dataclass(frozen=True)
class Interval:
    """A destination-segment word range ``[start, start + words)``.

    ``start=None`` means the address was traced: the analyzer must
    assume the interval may alias anything in the segment.
    """

    start: int | None
    words: int

    @property
    def known(self) -> bool:
        return self.start is not None

    def overlaps(self, other: "Interval") -> bool:
        if not (self.known and other.known):
            return True          # conservatively aliasing
        return (self.start < other.start + other.words
                and other.start < self.start + self.words)

    def __str__(self) -> str:
        if not self.known:
            return f"[?, ?+{self.words})"
        return f"[{self.start}, {self.start + self.words})"


@dataclasses.dataclass
class CommEvent:
    """One comm-op call site, as recorded at trace time."""

    seq: int                            # event index in trace order
    op: str                             # op name ("put_long", ...)
    pattern: tuple[tuple[int, int], ...]
    writes: tuple[Interval, ...] = ()   # intervals written at each dst
    reads: tuple[Interval, ...] = ()    # intervals read at each remote src
    token: int | None = None            # None = traced token
    acked: bool = False                 # earns one credit on `token`
    asynchronous: bool = False
    deferred_reply: bool = False        # ack routed through a ReplyMailbox
    defer_ack: bool = False             # ack ledgered at the receiver
    piggyback_token: int | None = None  # this packet carries that token's
                                        # deferred acks home (grants them)
    drains_deferred: bool = False       # drain_deferred_acks: ships the
                                        # residual ledger for `token`
    wait_n: int | None = None           # wait_replies count (None = traced)
    timeout: bool = False               # wait_replies: partial-drain path
    lossy: bool = False                 # traverses a fault-injecting link
    retries: int = 0                    # retransmit bound (0 = no retry)
    dedup: bool = True                  # receiver dedups redelivery (R5)
    credit_grants: tuple[tuple[int, int], ...] = ()  # (token, count) grants
    handler: int | None = None
    segment_words: int = 0
    mailbox_id: int | None = None       # id() of the flushing Mailbox
    ordered_ingress: bool = True        # strided: sequential-scan ingress?
    self_overlap: bool = False          # intra-op aliasing possible
    waiver: str | None = None
    tag: str = ""                       # "shoal.<op>#e<seq>" named scope
    detail: dict = dataclasses.field(default_factory=dict)

    @property
    def dsts(self) -> tuple[int, ...]:
        return tuple(sorted({d for _, d in self.pattern}))

    @property
    def srcs(self) -> tuple[int, ...]:
        return tuple(sorted({s for s, _ in self.pattern}))

    def site(self) -> str:
        return f"{self.op}#e{self.seq}"


class Recorder:
    """Collects :class:`CommEvent`s while installed (see :func:`record`)."""

    def __init__(self) -> None:
        self.events: list[CommEvent] = []

    def next_seq(self) -> int:
        return len(self.events)


_RECORDERS: list[Recorder] = []
_WAIVERS: list[str] = []
_TAG_COUNTER = [0]


def active() -> bool:
    return bool(_RECORDERS)


def current_waiver() -> str | None:
    return _WAIVERS[-1] if _WAIVERS else None


@contextlib.contextmanager
def record() -> Iterator[Recorder]:
    """Install a fresh recorder for the duration of a trace."""
    rec = Recorder()
    _RECORDERS.append(rec)
    try:
        yield rec
    finally:
        _RECORDERS.remove(rec)


@contextlib.contextmanager
def waiver(reason: str) -> Iterator[None]:
    """Mark comm ops in this block as deliberate (inline waiver).

    Findings whose every involved event carries a waiver are reported
    as waived and do not fail the lint.  The waiver also downgrades the
    op layer's *runtime* aliasing rejections (e.g. overlapping vectored
    destination addresses) to analyzer findings, so a deliberately
    order-dependent packet can be expressed at all.
    """
    if not reason or not str(reason).strip():
        raise ValueError("waiver() needs a non-empty reason string")
    _WAIVERS.append(str(reason))
    try:
        yield
    finally:
        _WAIVERS.pop()


def static_int(x) -> int | None:
    """``int(x)`` when ``x`` is trace-time concrete, else ``None``."""
    try:
        return int(x)
    except Exception:
        return None


def emit(op: str, pattern, **kw) -> str:
    """Record one comm event (if a recorder is active) and return the
    ``shoal.<op>#e<seq>`` scope tag for :func:`scope`.

    Tagging is unconditional — compiled programs always carry the call
    sites in their op metadata — but events are only stored while a
    :func:`record` block is active.
    """
    pat = tuple((int(s), int(d)) for s, d in pattern)
    if _RECORDERS:
        rec = _RECORDERS[-1]
        seq = rec.next_seq()
        tag = f"shoal.{op}#e{seq}"
        ev = CommEvent(seq=seq, op=op, pattern=pat, tag=tag,
                       waiver=current_waiver(), **kw)
        rec.events.append(ev)
        return tag
    _TAG_COUNTER[0] += 1
    return f"shoal.{op}#e{_TAG_COUNTER[0] - 1}"


def scope(tag: str):
    """Named scope wrapping an op's equations with its event tag."""
    return jax.named_scope(tag)


# --------------------------------------------------------------------------
# post-trace recovery: map jaxpr equations back to tagged call sites
# --------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(v):
    vals = v if isinstance(v, (tuple, list)) else (v,)
    for item in vals:
        inner = getattr(item, "jaxpr", None)
        if inner is not None:
            # ClosedJaxpr -> Jaxpr, or already a Jaxpr-like
            yield getattr(inner, "jaxpr", inner) if hasattr(inner, "eqns") \
                else inner
        elif hasattr(item, "eqns"):
            yield item


def recover_tags(closed_jaxpr) -> dict[str, int]:
    """Walk a (closed) jaxpr and count equations per ``shoal.*`` tag.

    Returns ``{tag: eqn_count}`` — the post-trace view of which comm
    call sites made it into the program.  Used by the linter to
    cross-check that every recorded event is recoverable from the jaxpr
    alone (and by debugging tools to attribute equations to ops).
    """
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    tags: dict[str, int] = {}
    for eqn in _iter_eqns(jaxpr):
        try:
            stack = str(eqn.source_info.name_stack)
        except Exception:
            continue
        for part in stack.split("/"):
            if part.startswith("shoal."):
                tags[part] = tags.get(part, 0) + 1
    return tags


def intervals_for_blocks(addrs, sizes) -> tuple[Interval, ...]:
    """Per-block :class:`Interval`s for a vectored address list; traced
    addresses become unknown intervals."""
    out = []
    for a, w in zip(addrs, sizes):
        out.append(Interval(static_int(a), int(w)))
    return tuple(out)
