"""Finding/report model shared by both shoal-lint passes.

Rule catalog (see README "Static analysis"):

  R1  write-write overlap: two segment writes to overlapping destination
      intervals with no ordering (ack wait / barrier) between them — the
      PR 6 strided-ingress race class, generalized to any op pair.
  R2  read-after-unordered-write: a get of a segment range with an
      in-flight put (no ``wait_replies`` on the put's token, no barrier)
      overlapping that range.
  R3  credit-flow errors: ``wait_replies`` draining more credits than
      the schedule issued (the trace-time form of the runtime
      ``ERR_WAIT_UNDERFLOW``), credits earned but never consumed
      (leaked acks), and one token fed by several mailboxes with no
      wait between flushes (double-spend hazard).
  R4  addressing errors: statically out-of-bounds destination or source
      intervals (the GAScore clips these silently at runtime), and
      aliasing/duplicate destination addresses inside one vectored
      address list (order-dependent scatter).
  B1  collective-budget violations: a compiled entry point exceeds its
      declared budget in ``comm_budgets.toml`` (pass 2).
"""

from __future__ import annotations

import dataclasses

ERROR = "error"
WARNING = "warning"

RULES = {
    "R1": "write-write overlap without ordering",
    "R2": "read overlapping an in-flight write",
    "R3": "credit-flow error (underflow / leak / double-spend)",
    "R4": "out-of-bounds or aliasing address list",
    "B1": "collective budget exceeded",
}


@dataclasses.dataclass
class Finding:
    rule: str
    message: str
    severity: str = ERROR
    events: tuple[int, ...] = ()        # seq ids of involved CommEvents
    sites: tuple[str, ...] = ()         # "op#eN" call-site names
    waived: str | None = None           # waiver reason, if annotated

    def render(self) -> str:
        sev = "WAIVED" if self.waived else self.severity.upper()
        at = f" at {', '.join(self.sites)}" if self.sites else ""
        note = f" (waiver: {self.waived})" if self.waived else ""
        return f"[{self.rule}/{sev}]{at}: {self.message}{note}"


@dataclasses.dataclass
class Report:
    """Outcome of linting one entry point (either pass)."""

    entry: str
    findings: list[Finding] = dataclasses.field(default_factory=list)
    n_events: int = 0
    tags_recovered: int = 0             # distinct shoal.* tags in the jaxpr
    wall_time_s: float = 0.0
    budget: dict = dataclasses.field(default_factory=dict)  # pass-2 table row

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings
                if f.severity == ERROR and not f.waived]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings
                if f.severity == WARNING and not f.waived]

    @property
    def waived(self) -> list[Finding]:
        return [f for f in self.findings if f.waived]

    @property
    def ok(self) -> bool:
        """Clean = no unwaived findings of any severity."""
        return not self.errors and not self.warnings

    def extend(self, findings) -> "Report":
        self.findings.extend(findings)
        return self

    def render(self) -> str:
        head = (f"shoal-lint {self.entry}: {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s), "
                f"{len(self.waived)} waived, {self.n_events} comm event(s)")
        lines = [head]
        lines.extend("  " + f.render() for f in self.findings)
        return "\n".join(lines)


class CommLintError(AssertionError):
    """Raised by ``lint_clean`` when a program has unwaived findings."""

    def __init__(self, report: Report):
        self.report = report
        super().__init__(report.render())
