"""shoal-lint: trace-time PGAS comm-safety analysis (see README
"Static analysis").

Pass 1 (:mod:`.jaxpr_lint`) records the comm schedule while tracing and
runs rules R1-R4 (:mod:`.rules`) over it; pass 2 (:mod:`.hlo_budget`)
diffs compiled-HLO collective counts against ``comm_budgets.toml``.
Both produce the shared :class:`.report.Report` model;
:mod:`.registry` names the entry points CI runs them over.

This ``__init__`` stays import-light on purpose: :mod:`repro.core.ops`
imports :mod:`.trace` at module load, so pulling in the linter (which
imports jax transforms) or the registry (which imports apps/serving)
here would be a cycle.  Those resolve lazily via ``__getattr__``.
"""

from repro.analysis.report import (CommLintError, ERROR, Finding, Report,
                                   RULES, WARNING)
from repro.analysis.trace import (CommEvent, Interval, Recorder, emit,
                                  record, scope, waiver)

__all__ = [
    "CommEvent", "CommLintError", "ERROR", "Finding", "Interval",
    "Recorder", "Report", "RULES", "WARNING", "analyze", "emit",
    "hlo_budget", "jaxpr_lint", "lint", "lint_clean", "lint_events",
    "record", "registry", "rules", "scope", "trace", "waiver",
]

_LAZY = {
    "lint": ("repro.analysis.jaxpr_lint", "lint"),
    "lint_clean": ("repro.analysis.jaxpr_lint", "lint_clean"),
    "lint_events": ("repro.analysis.jaxpr_lint", "lint_events"),
    "analyze": ("repro.analysis.rules", "analyze"),
    "jaxpr_lint": ("repro.analysis.jaxpr_lint", None),
    "hlo_budget": ("repro.analysis.hlo_budget", None),
    "registry": ("repro.analysis.registry", None),
    "rules": ("repro.analysis.rules", None),
    "trace": ("repro.analysis.trace", None),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(mod_name)
    return mod if attr is None else getattr(mod, attr)
