"""Pass 2: compiled-HLO collective budgets (the generalization of
``tests/hlo_budget_checks.py``).

The measured side reuses :func:`repro.launch.hlo_analysis.parse_collectives`
— trip-count-weighted collective counts and ring-model bytes-on-wire per
compiled program.  The declared side is the checked-in
``comm_budgets.toml`` at the repo root: one ``[section]`` per budgeted
program, keys of the form ``<metric>_max`` / ``<metric>_exact`` where
``metric`` is one of::

    collective_permute  all_to_all  all_gather  all_reduce
    reduce_scatter      total_collectives       wire_bytes

Violations become rule-B1 findings in the same report model as pass 1,
so the CLI / CI / pytest fixture treat "too many collectives" exactly
like a race.  A budgeted program with *no* section is a B1 warning —
budgets must stay checked in, or regressions land silently.

Python 3.10 has no ``tomllib``, so a deliberately tiny parser handles
the subset the budget file uses (sections, numeric/string/bool values,
comments).  Anything it cannot parse is a hard error, not a guess.
"""

from __future__ import annotations

import os

from repro.analysis.report import ERROR, WARNING, Finding
from repro.launch.hlo_analysis import CollectiveStats, parse_collectives

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_BUDGETS_PATH = os.path.join(_REPO_ROOT, "comm_budgets.toml")

# budget-key metric -> CollectiveStats.ops kind (None = derived metric)
_KINDS = {
    "collective_permute": "collective-permute",
    "all_to_all": "all-to-all",
    "all_gather": "all-gather",
    "all_reduce": "all-reduce",
    "reduce_scatter": "reduce-scatter",
}


def parse_budget_toml(text: str) -> dict[str, dict]:
    """Parse the comm_budgets.toml subset: [sections] of key = value."""
    out: dict[str, dict] = {}
    cur: dict | None = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("[") and line.endswith("]"):
            name = line[1:-1].strip().strip('"')
            cur = out.setdefault(name, {})
            continue
        if "=" not in line or cur is None:
            raise ValueError(
                f"comm_budgets.toml line {lineno}: cannot parse {raw!r}")
        key, val = (s.strip() for s in line.split("=", 1))
        if val.startswith('"') and val.endswith('"'):
            cur[key] = val[1:-1]
        elif val in ("true", "false"):
            cur[key] = val == "true"
        else:
            try:
                num = float(val)
            except ValueError:
                raise ValueError(
                    f"comm_budgets.toml line {lineno}: bad value {val!r}")
            cur[key] = int(num) if num.is_integer() and "." not in val \
                and "e" not in val.lower() else num
    return out


def load_budgets(path: str | None = None) -> dict[str, dict]:
    with open(path or DEFAULT_BUDGETS_PATH) as f:
        return parse_budget_toml(f.read())


def measure(hlo_text: str) -> CollectiveStats:
    """Collective stats of one compiled program (pass-2 measurement)."""
    return parse_collectives(hlo_text)


def _metric(stats: CollectiveStats, base: str) -> float | None:
    if base == "total_collectives":
        return float(sum(stats.ops.values()))
    if base == "wire_bytes":
        return float(stats.wire_bytes)
    kind = _KINDS.get(base)
    return None if kind is None else float(stats.ops.get(kind, 0.0))


def check_budget(entry: str, stats: CollectiveStats,
                 spec: dict | None) -> list[Finding]:
    """Diff measured stats against one budget section; B1 findings."""
    if not spec:
        return [Finding(
            rule="B1", severity=WARNING,
            message=(f"no [{entry}] section in comm_budgets.toml — "
                     "declare a collective budget so wire-cost "
                     "regressions in this program are caught"))]
    out: list[Finding] = []
    for key, want in spec.items():
        if isinstance(want, str):            # note/doc keys
            continue
        if key.endswith("_max"):
            base, exact = key[:-4], False
        elif key.endswith("_exact"):
            base, exact = key[:-6], True
        else:
            raise ValueError(
                f"comm_budgets.toml [{entry}]: unknown key {key!r} "
                "(want <metric>_max or <metric>_exact)")
        got = _metric(stats, base)
        if got is None:
            raise ValueError(
                f"comm_budgets.toml [{entry}]: unknown metric {base!r}")
        bad = (abs(got - want) > 1e-6) if exact else (got > want + 1e-6)
        if bad:
            rel = "!=" if exact else ">"
            out.append(Finding(
                rule="B1", severity=ERROR,
                message=(f"{entry}: measured {base.replace('_', '-')} "
                         f"{got:g} {rel} declared budget {want:g} — the "
                         "compiled program's wire cost drifted from "
                         f"comm_budgets.toml [{entry}]")))
    return out


def budget_row(stats: CollectiveStats, spec: dict | None) -> dict:
    """JSON-ready table row: measured counts/bytes + declared budget."""
    return {
        "ops": {k: round(v, 3) for k, v in sorted(stats.ops.items())},
        "wire_bytes": round(float(stats.wire_bytes), 1),
        "budget": dict(spec or {}),
    }
