"""jit'd wrappers for the Jacobi kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.jacobi.jacobi import jacobi_step_pallas
from repro.kernels.jacobi.ref import jacobi_step_ref


def _pick_block_rows(m: int, want: int = 256) -> int:
    for b in (want, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % b == 0:
            return b
    return 1


def jacobi_step(x: jnp.ndarray, *, use_pallas: bool = True,
                interpret: bool = True) -> jnp.ndarray:
    """One iteration; pallas kernel or jnp oracle."""
    if not use_pallas:
        return jacobi_step_ref(x)
    return jacobi_step_pallas(x, block_rows=_pick_block_rows(x.shape[0]),
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("iters", "use_pallas", "interpret"))
def jacobi_run(x: jnp.ndarray, iters: int, *, use_pallas: bool = False,
               interpret: bool = True) -> jnp.ndarray:
    """``iters`` Jacobi iterations (lax.fori_loop over the step)."""
    def body(_, g):
        return jacobi_step(g, use_pallas=use_pallas, interpret=interpret)
    return jax.lax.fori_loop(0, iters, body, x)
