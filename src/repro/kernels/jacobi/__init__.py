from repro.kernels.jacobi.ops import jacobi_step, jacobi_run
from repro.kernels.jacobi.ref import jacobi_step_ref

__all__ = ["jacobi_step", "jacobi_run", "jacobi_step_ref"]
