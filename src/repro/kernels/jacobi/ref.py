"""Pure-jnp oracle for the Jacobi von Neumann stencil (paper Sec. IV-C)."""

import jax.numpy as jnp


def jacobi_step_ref(x: jnp.ndarray) -> jnp.ndarray:
    """One Jacobi iteration: interior cells become the mean of their four
    von Neumann neighbors; boundary cells are fixed (Dirichlet)."""
    up = x[:-2, 1:-1]
    down = x[2:, 1:-1]
    left = x[1:-1, :-2]
    right = x[1:-1, 2:]
    interior = 0.25 * (up + down + left + right)
    return x.at[1:-1, 1:-1].set(interior.astype(x.dtype))
