"""Blocked Jacobi stencil kernel (Pallas, TPU target).

TPU adaptation of the paper's VHDL compute core: instead of a
streaming-row systolic pipeline, we tile the grid into VMEM-resident
row bands sized for the vector unit.  Each program instance owns a
``(block_rows, N)`` band; the up/down halo rows arrive as two extra
row-shifted *views* of the padded input (three inputs, one standard
BlockSpec each — overlapping windows expressed as shifted views keeps
the index maps affine, which is what Mosaic wants).  Left/right
neighbors are in-band column shifts.

VMEM budget: 4 bands x block_rows x N x 4 B.  At the default
``block_rows=256`` and N=2048 that is 8 MB — comfortably under the
16 MB/core VMEM of v5e, with N itself blocked for larger grids by the
wrapper.  Rows are multiples of 8 and columns of 128 (f32 tiling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(up_ref, mid_ref, down_ref, out_ref, *, m_total: int,
                   block_rows: int):
    i = pl.program_id(0)
    up = up_ref[...]
    mid = mid_ref[...]
    down = down_ref[...]
    rows, n = mid.shape

    left = jnp.roll(mid, 1, axis=1)     # column j-1
    right = jnp.roll(mid, -1, axis=1)   # column j+1
    stencil = 0.25 * (up + down + left + right)

    # masks: first/last global row and first/last column are boundary
    grow = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (rows, n), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1)
    interior = ((grow > 0) & (grow < m_total - 1)
                & (gcol > 0) & (gcol < n - 1))
    out_ref[...] = jnp.where(interior, stencil.astype(mid.dtype), mid)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def jacobi_step_pallas(x: jnp.ndarray, *, block_rows: int = 256,
                       interpret: bool = True) -> jnp.ndarray:
    """One Jacobi iteration over x (M, N); M % block_rows == 0."""
    m, n = x.shape
    assert m % block_rows == 0, (m, block_rows)
    # row-shifted views (zero-padded top/bottom; the boundary mask makes
    # the padding value irrelevant)
    up = jnp.pad(x[:-1], ((1, 0), (0, 0)))
    down = jnp.pad(x[1:], ((0, 1), (0, 0)))

    grid = (m // block_rows,)
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_jacobi_kernel, m_total=m, block_rows=block_rows),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(up, x, down)
