"""Blocked causal flash attention (Pallas, TPU target).

The canonical Pallas-TPU pattern: grid (batch*heads, n_q_blocks,
n_k_blocks) with the k axis innermost; the output block index map
ignores the k coordinate so the same (BQ, dh) output tile is revisited
across k steps while running max / normalizer / accumulator live in
VMEM scratch.  MXU alignment: BQ, BK, dh are multiples of 128 in the
production config (tests sweep smaller interpret-mode tiles).

VMEM working set per step: q (BQ x dh) + k,v (BK x dh each) + acc
(BQ x dh) + m,l (BQ) — at BQ=BK=512, dh=128, f32 accumulation that is
~1.3 MB, leaving room for double buffering in the 16 MB/core VMEM.

Causality is enforced by masking within the diagonal block and by
skipping (masking to zero contribution) fully-future k blocks; the
wrapper truncates the k grid per q block is left to the compiler's
revisit schedule (structurally simple version — the production variant
would use a triangular grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (BQ, dh)
    k = k_ref[0]                                  # (BK, dh)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1)
    acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ki == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "sm_scale"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sm_scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q,k,v: (BH, S, dh) -> (BH, S, dh).  S % block == 0 (wrapper pads)."""
    bh, s, dh = q.shape
    scale = float(sm_scale if sm_scale is not None else 1.0 / np.sqrt(dh))
    n_q = s // block_q
    n_k = s // block_k
    grid = (bh, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # normalizer l
            pltpu.VMEM((block_q, dh), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
