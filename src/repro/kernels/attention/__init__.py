from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]
