"""Pure-jnp oracle for causal attention."""

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True,
                  sm_scale: float | None = None) -> jnp.ndarray:
    """q,k,v: (BH, S, dh) -> (BH, S, dh)."""
    s = q.shape[1]
    dh = q.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", probs, v)
