"""jit'd wrapper: pad to block multiples, reshape heads, kernel/oracle."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.attention.flash import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, use_pallas: bool = True,
                    interpret: bool = True):
    """q,k,v: (BH, S, dh).  Pads S up to a block multiple (padded key rows
    are masked out by causality given padded queries are discarded)."""
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal)
    bh, s, dh = q.shape
    blk = max(block_q, block_k)
    pad = (-s) % blk
    if pad:
        padf = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        out = flash_attention_pallas(padf(q), padf(k), padf(v), causal=True,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
        return out[:, :s]
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
