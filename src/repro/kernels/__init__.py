"""Pallas TPU kernels for the framework's compute hot-spots.

Three kernels, each with a pure-jnp oracle (``ref.py``) and a jit'd
wrapper (``ops.py``), validated in interpret mode on CPU (TPU is the
lowering target):

* ``jacobi``      — the paper's stencil application hot loop (Sec. IV-C).
* ``am_pack``     — strided gather/scatter for Strided Long AMs: the
  GAScore's DataMover datapath (Sec. III-C).
* ``attention``   — blocked causal flash attention: the dominant FLOP
  consumer of the LM framework the Shoal substrate carries.
* ``gascore_dma`` — ring all-reduce on ``pltpu.make_async_remote_copy``:
  the literal GAScore (one-sided RDMA Long put + ADD handler) as a
  Pallas kernel, validated via Pallas distributed interpret.
"""
