"""Oracle: the ring all-reduce is just a psum."""

import jax


def ring_allreduce_ref(x, axis_name):
    return jax.lax.psum(x, axis_name)
