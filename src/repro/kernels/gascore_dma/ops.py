"""shard_map wrapper for the RDMA ring all-reduce."""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.kernels.gascore_dma.gascore_dma import ring_allreduce_dma_local


def ring_allreduce_dma(mesh, axis_name: str, x, *, interpret: bool = True):
    """x: global (n*chunk,) array sharded over ``axis_name``; returns the
    all-reduced value with the same sharding (every shard = total sum of
    its position's blocks ... i.e. each device's block becomes the sum of
    all devices' blocks)."""
    n = mesh.shape[axis_name]

    def body(xl):
        return ring_allreduce_dma_local(xl, axis_name=axis_name, n=n,
                                        interpret=interpret)

    return shard_map(body, mesh=mesh, in_specs=P(axis_name),
                         out_specs=P(axis_name), check_vma=False)(x)
