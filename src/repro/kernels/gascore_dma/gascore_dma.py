"""The GAScore as a Pallas kernel: ring all-reduce on one-sided RDMA.

This is the most literal TPU realization of the paper's contribution:
``pltpu.make_async_remote_copy`` *is* a one-sided Long AM put — a DMA
engine writes a payload into a remote chip's memory with no receiver
code — and the DMA semaphores are the AM reply/credit counters
(the GAScore's hold-buffer ordering becomes ``copy.wait()``).  The ring
all-reduce below is the Long-put-with-ADD-handler datapath (paper
Sec. III-C) scheduled around the ICI ring, the hardware twin of
:func:`repro.core.collectives.ring_all_reduce` (which expresses the same
schedule through XLA collective-permutes).

Algorithm (all-gather-reduce ring, n-1 steps): every device pushes its
``carry`` block to its right neighbor's inbox slot and accumulates what
arrived from the left.  Double-buffered inbox; in a production kernel a
reverse *capacity* semaphore ring would guard slot reuse beyond the
1-step slack (the AM credit counter, again) — interpret mode and
lockstep grids do not need it, so it is omitted here for clarity.

Validated in interpret mode (Pallas distributed interpret executes the
remote DMAs across the host devices); on real v5e this lowers to ICI
RDMA.  VMEM: 3 chunk-sized buffers + the output — chunks up to ~1 MW
f32 fit comfortably.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ring_kernel(x_ref, o_ref, carry, inbox, send_sem, recv_sem, *,
                 axis_name: str, n: int):
    me = lax.axis_index(axis_name)
    right = lax.rem(me + 1, n)

    o_ref[...] = x_ref[...]
    carry[...] = x_ref[...]

    def step(t, _):
        slot = lax.rem(t, 2)
        # one-sided Long put of my carry into the right neighbor's inbox
        copy = pltpu.make_async_remote_copy(
            src_ref=carry, dst_ref=inbox.at[slot],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=right, device_id_type=pltpu.DeviceIdType.LOGICAL)
        copy.start()
        copy.wait()          # send drained + my inbox filled (the "reply")
        carry[...] = inbox[slot]          # what my left neighbor sent
        o_ref[...] = o_ref[...] + carry[...]   # the ADD handler
        return 0

    lax.fori_loop(0, n - 1, step, 0)


@functools.partial(jax.jit, static_argnames=("axis_name", "n", "interpret"))
def ring_allreduce_dma_local(x, *, axis_name: str, n: int,
                             interpret: bool = True):
    """Per-device body (inside shard_map over ``axis_name``).
    x: (chunk,) local block -> (chunk,) sum over all n devices."""
    chunk = x.shape[0]
    return pl.pallas_call(
        functools.partial(_ring_kernel, axis_name=axis_name, n=n),
        out_shape=jax.ShapeDtypeStruct((chunk,), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((chunk,), x.dtype),        # carry
            pltpu.VMEM((2, chunk), x.dtype),      # double-buffered inbox
            pltpu.SemaphoreType.DMA,              # send
            pltpu.SemaphoreType.DMA,              # recv
        ],
        interpret=interpret,
    )(x)
