from repro.kernels.gascore_dma.ops import ring_allreduce_dma
from repro.kernels.gascore_dma.ref import ring_allreduce_ref

__all__ = ["ring_allreduce_dma", "ring_allreduce_ref"]
