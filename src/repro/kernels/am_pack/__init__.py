from repro.kernels.am_pack.ops import am_pack, am_unpack
from repro.kernels.am_pack.ref import (am_pack_ref, am_unpack_ref,
                                       strided_indices)

__all__ = ["am_pack", "am_unpack", "am_pack_ref", "am_unpack_ref",
           "strided_indices"]
