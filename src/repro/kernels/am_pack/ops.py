"""jit'd wrappers: pallas kernel with jnp-oracle fallback."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.am_pack.am_pack import am_pack_pallas, am_unpack_pallas
from repro.kernels.am_pack.ref import am_pack_ref, am_unpack_ref


def am_pack(segment: jnp.ndarray, addr: int, stride: int, blk_words: int,
            nblocks: int, *, use_pallas: bool = True,
            interpret: bool = True) -> jnp.ndarray:
    if not use_pallas:
        return am_pack_ref(segment, addr, stride, blk_words, nblocks)
    return am_pack_pallas(segment, addr, stride=stride, blk_words=blk_words,
                          nblocks=nblocks, interpret=interpret)


def am_unpack(segment: jnp.ndarray, payload: jnp.ndarray, addr: int,
              stride: int, blk_words: int, nblocks: int, *,
              use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    if not use_pallas:
        return am_unpack_ref(segment, payload, addr, stride, blk_words, nblocks)
    return am_unpack_pallas(segment, payload, addr, stride=stride,
                            blk_words=blk_words, nblocks=nblocks,
                            interpret=interpret)
