"""Strided AM pack/unpack kernels (Pallas, TPU target).

This is the GAScore's DataMover datapath for Strided Long AMs (paper
Sec. III-A/III-C): gathering a strided region of the shared-memory
segment into a contiguous wire payload, and scattering on ingress.

TPU adaptation: the FPGA DataMover issues one AXI burst per block; here
each grid step copies one block from the segment (kept whole in VMEM —
segments are small by construction; an HBM-resident variant would swap
the in_spec to ANY and ``pl.ds`` DMA per block) into its slot of the
packed payload.  ``blk_words`` is padded to the 128-lane boundary by the
wrapper so every copy is lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(seg_ref, out_ref, *, addr, stride, blk_words):
    i = pl.program_id(0)
    start = addr + i * stride
    out_ref[...] = jax.lax.dynamic_slice(seg_ref[...], (start,), (blk_words,))


def _unpack_kernel(pay_ref, seg_in_ref, seg_ref, *, addr, stride, blk_words,
                   nblocks):
    # single program: sequential scatter of all blocks (stride may alias)
    def body(i, seg):
        blk = jax.lax.dynamic_slice(pay_ref[...], (i * blk_words,), (blk_words,))
        return jax.lax.dynamic_update_slice(seg, blk, (addr + i * stride,))
    seg_ref[...] = jax.lax.fori_loop(0, nblocks, body, seg_in_ref[...])


@functools.partial(jax.jit, static_argnames=("addr", "stride", "blk_words",
                                             "nblocks", "interpret"))
def am_pack_pallas(segment: jnp.ndarray, addr: int, *, stride: int,
                   blk_words: int, nblocks: int,
                   interpret: bool = True) -> jnp.ndarray:
    S = segment.shape[0]
    return pl.pallas_call(
        functools.partial(_pack_kernel, addr=addr, stride=stride,
                          blk_words=blk_words),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((S,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk_words,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks * blk_words,), segment.dtype),
        interpret=interpret,
    )(segment)


@functools.partial(jax.jit, static_argnames=("addr", "stride", "blk_words",
                                             "nblocks", "interpret"))
def am_unpack_pallas(segment: jnp.ndarray, payload: jnp.ndarray, addr: int, *,
                     stride: int, blk_words: int, nblocks: int,
                     interpret: bool = True) -> jnp.ndarray:
    S = segment.shape[0]
    P = payload.shape[0]
    return pl.pallas_call(
        functools.partial(_unpack_kernel, addr=addr, stride=stride,
                          blk_words=blk_words, nblocks=nblocks),
        grid=(1,),
        in_specs=[pl.BlockSpec((P,), lambda i: (0,)),
                  pl.BlockSpec((S,), lambda i: (0,))],
        out_specs=pl.BlockSpec((S,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((S,), segment.dtype),
        input_output_aliases={1: 0},   # in-place segment update
        interpret=interpret,
    )(payload, segment)
