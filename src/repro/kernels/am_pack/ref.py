"""Oracles for the strided AM pack/unpack (GAScore DataMover path)."""

import jax.numpy as jnp


def strided_indices(addr, stride, blk_words: int, nblocks: int) -> jnp.ndarray:
    """Flat ``(nblocks * blk_words,)`` gather/scatter index map for a
    strided region: lane ``i*blk_words + j`` maps to ``addr + i*stride + j``.

    ``addr`` and ``stride`` may be traced; the block geometry is static.
    Shared by the pack/unpack oracles here and by the GAScore's
    vectorized strided ingress (:mod:`repro.core.gascore`).
    """
    idx = (addr + stride * jnp.arange(nblocks)[:, None]
           + jnp.arange(blk_words)[None, :])
    return idx.reshape(-1)


def am_pack_ref(segment: jnp.ndarray, addr: int, stride: int,
                blk_words: int, nblocks: int) -> jnp.ndarray:
    """Gather ``nblocks`` blocks of ``blk_words`` at addr + i*stride from
    a 1-D segment into a contiguous payload."""
    return segment[strided_indices(addr, stride, blk_words, nblocks)]


def am_unpack_ref(segment: jnp.ndarray, payload: jnp.ndarray, addr: int,
                  stride: int, blk_words: int, nblocks: int) -> jnp.ndarray:
    """Scatter a packed payload back at addr + i*stride."""
    idx = strided_indices(addr, stride, blk_words, nblocks)
    return segment.at[idx].set(payload)
