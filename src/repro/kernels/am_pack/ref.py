"""Oracles for the strided AM pack/unpack (GAScore DataMover path)."""

import jax.numpy as jnp


def am_pack_ref(segment: jnp.ndarray, addr: int, stride: int,
                blk_words: int, nblocks: int) -> jnp.ndarray:
    """Gather ``nblocks`` blocks of ``blk_words`` at addr + i*stride from
    a 1-D segment into a contiguous payload."""
    idx = (addr + stride * jnp.arange(nblocks)[:, None]
           + jnp.arange(blk_words)[None, :])
    return segment[idx.reshape(-1)]


def am_unpack_ref(segment: jnp.ndarray, payload: jnp.ndarray, addr: int,
                  stride: int, blk_words: int, nblocks: int) -> jnp.ndarray:
    """Scatter a packed payload back at addr + i*stride."""
    idx = (addr + stride * jnp.arange(nblocks)[:, None]
           + jnp.arange(blk_words)[None, :])
    return segment.at[idx.reshape(-1)].set(payload)
