"""Routing: kernel IDs <-> mesh coordinates and link classification.

libGalapagos routes packets between local kernels in software and hands
off-node traffic to the network driver.  The XLA analogue: traffic whose
source and destination are the same chip never becomes a collective
(LOCAL short-circuit); intra-pod traffic lowers to collective-permute on
ICI; inter-pod traffic crosses the DCN ("pod") axis.
"""

from __future__ import annotations

import dataclasses

from repro.runtime.topology import ClusterSpec, kernel_coords, pod_of
from repro.runtime.transport import LinkClass


@dataclasses.dataclass(frozen=True)
class Router:
    spec: ClusterSpec

    def classify(self, src: int, dst: int) -> LinkClass:
        """Which link class a src->dst AM traverses."""
        if src == dst:
            return LinkClass.LOCAL
        if pod_of(self.spec, src) != pod_of(self.spec, dst):
            return LinkClass.DCN
        return LinkClass.ICI

    def classify_pattern(self, pattern: list[tuple[int, int]]) -> LinkClass:
        """Worst link class over a pattern (the paper reports per-topology
        numbers; a mixed pattern is bounded by its slowest hop)."""
        worst = LinkClass.LOCAL
        for s, d in pattern:
            c = self.classify(s, d)
            if c.value > worst.value:
                worst = c
        return worst

    def coords(self, kernel_id: int) -> dict[str, int]:
        return kernel_coords(self.spec, kernel_id)

    def is_pure_local(self, pattern: list[tuple[int, int]]) -> bool:
        return all(s == d for s, d in pattern)
