"""Version-compatibility shims over the JAX public API.

The library targets current JAX (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``) but must also run on older releases
where those names live under ``jax.experimental`` or do not exist.  Every
module that needs one of these symbols imports it from here instead of
probing ``jax`` itself.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

if hasattr(jax, "shard_map"):  # jax >= 0.6
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _spec_axis_names(tree) -> set:
        """Every mesh axis name mentioned by any PartitionSpec leaf."""
        from jax.sharding import PartitionSpec as _P

        names: set = set()
        for leaf in jax.tree_util.tree_leaves(
                tree, is_leaf=lambda x: isinstance(x, _P)):
            if not isinstance(leaf, _P):
                continue
            for entry in leaf:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    names.add(ax)
        return names

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, **kw):
        """Adapter onto the pre-0.6 experimental API: ``check_vma`` was
        called ``check_rep``.

        ``axis_names`` (partial-manual mode) has no old-jax equivalent —
        the old partial-auto lowering hits "PartitionId is not
        supported" on the CPU SPMD partitioner — so the region runs
        FULLY manual instead.  That fallback is only sound while the
        auto (non-manual) axes stay *unnamed* in the specs: unnamed
        axes merely replicate, which changes cost but not values.  A
        spec that shards an argument over an auto axis of size > 1
        would be silently dropped to replication, changing per-shard
        shapes and semantics inside ``f`` — that case raises instead of
        miscomputing.
        """
        if axis_names is not None:
            auto = set(mesh.axis_names) - set(axis_names)
            bad = sorted(
                a for a in _spec_axis_names((in_specs, out_specs))
                if a in auto and mesh.shape[a] > 1)
            if bad:
                raise NotImplementedError(
                    f"jax {jax.__version__} shard_map shim: partial-manual "
                    f"regions fall back to fully-manual, which cannot honor "
                    f"specs that shard over the auto (GSPMD) axes {bad}; "
                    "drop those axes from the specs (replicate) or upgrade "
                    "to jax >= 0.6 for true partial-manual mode")
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)


def bound_axis_names() -> frozenset:
    """Mesh axis names currently bound as *manual* axes (i.e. we are
    tracing inside a ``shard_map``/``pmap`` region over them).  Empty on
    jax >= 0.6, where partial-manual mode tracks this itself and nested
    sharding annotations over auto axes are legal."""
    if hasattr(jax, "shard_map"):
        return frozenset()
    try:  # pragma: no cover - old-jax introspection
        from jax._src import core as _core
        env = _core.get_axis_env()
        return frozenset(n for n in env.axis_sizes if isinstance(n, str))
    except Exception:  # pragma: no cover
        return frozenset()


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported
    (silences the 0.9 deprecation); plain mesh on older releases."""
    if AxisType is not None:
        return jax.make_mesh(
            tuple(shape), tuple(names), axis_types=(AxisType.Auto,) * len(shape)
        )
    return jax.make_mesh(tuple(shape), tuple(names))
