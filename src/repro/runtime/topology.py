"""Cluster topology: the Galapagos cluster-description analogue.

Galapagos turns user configuration files into a deployed cluster of
CPU/FPGA nodes, each holding one or more kernels.  Here a "cluster" is a
JAX device mesh: pods (DCN-connected) x chips (ICI-connected), and a
"kernel" is one per-device program instance under ``shard_map``.  The
kernel ID of the paper is the flattened mesh index.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import numpy as np

from repro.runtime.jax_compat import make_mesh as _compat_make_mesh


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Static description of a cluster (the Galapagos config-file analogue).

    Attributes:
      mesh_shape: devices per named axis, e.g. ``(2, 16, 16)``.
      axis_names: names per axis, e.g. ``("pod", "data", "model")``.
      kernel_axes: the axes over which Shoal kernels are enumerated.  By
        default all axes: every device in the mesh is one kernel.
      pod_axis: name of the inter-pod (DCN) axis, or None for single-pod.
    """

    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    kernel_axes: tuple[str, ...] | None = None
    pod_axis: str | None = None

    def __post_init__(self):
        if len(self.mesh_shape) != len(self.axis_names):
            raise ValueError("mesh_shape and axis_names must have equal length")
        if self.kernel_axes is None:
            object.__setattr__(self, "kernel_axes", tuple(self.axis_names))
        for ax in self.kernel_axes:
            if ax not in self.axis_names:
                raise ValueError(f"kernel axis {ax!r} not in {self.axis_names}")
        if self.pod_axis is not None and self.pod_axis not in self.axis_names:
            raise ValueError(f"pod axis {self.pod_axis!r} not in {self.axis_names}")

    @property
    def num_devices(self) -> int:
        return math.prod(self.mesh_shape)

    @property
    def num_kernels(self) -> int:
        n = 1
        for ax, size in zip(self.axis_names, self.mesh_shape):
            if ax in self.kernel_axes:
                n *= size
        return n

    def axis_size(self, name: str) -> int:
        return self.mesh_shape[self.axis_names.index(name)]

    def make(self) -> jax.sharding.Mesh:
        return make_mesh(self.mesh_shape, self.axis_names)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """Build a mesh with explicit Auto axis types (silences 0.9 deprecation)."""
    return _compat_make_mesh(shape, names)


def make_cpu_mesh(n: int | None = None, names: tuple[str, ...] = ("kernel",)):
    """1-D mesh over however many (host) devices exist; used by the
    microbenchmarks and semantic tests that emulate a multi-node cluster
    with ``--xla_force_host_platform_device_count``."""
    avail = len(jax.devices())
    n = avail if n is None else n
    if n > avail:
        raise ValueError(f"requested {n} devices, only {avail} available")
    return make_mesh((n,), names)


def kernel_coords(spec: ClusterSpec, kernel_id: int) -> dict[str, int]:
    """kernel ID -> per-axis coordinates (row-major over kernel_axes)."""
    sizes = [spec.axis_size(a) for a in spec.kernel_axes]
    coords: dict[str, int] = {}
    rem = kernel_id
    for ax, size in zip(reversed(spec.kernel_axes), reversed(sizes)):
        coords[ax] = rem % size
        rem //= size
    if rem:
        raise ValueError(f"kernel id {kernel_id} out of range")
    return coords


def pod_of(spec: ClusterSpec, kernel_id: int) -> int:
    """Which pod a kernel lives on (0 if single-pod)."""
    if spec.pod_axis is None or spec.pod_axis not in spec.kernel_axes:
        return 0
    return kernel_coords(spec, kernel_id)[spec.pod_axis]


def neighbors_ring(n: int, shift: int = 1) -> list[tuple[int, int]]:
    """Ring permutation pattern (the workhorse of ring collectives)."""
    return [(i, (i + shift) % n) for i in range(n)]


def pairwise(pairs: Sequence[tuple[int, int]]) -> list[tuple[int, int]]:
    """Validate an explicit src->dst pattern (each src/dst at most once,
    mirroring one outstanding AM per kernel per call)."""
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
        raise ValueError("pattern must have unique sources and destinations")
    return list(pairs)
