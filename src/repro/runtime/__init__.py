"""Galapagos-analogue runtime: topology, transports, routing.

The paper builds Shoal on top of Galapagos, which provides (a) cluster
creation/deployment, (b) a swappable network transport (TCP/UDP/raw
Ethernet), and (c) routing of packets to kernels.  On a TPU pod the
same three concerns exist and live here:

* :mod:`repro.runtime.topology`  -- cluster/mesh creation (pods x chips),
  the analogue of Galapagos' cluster description files.
* :mod:`repro.runtime.transport` -- delivery semantics (acked vs async,
  packet-size limits) and the per-link-class performance model; the
  analogue of choosing TCP/UDP in the Galapagos middleware layer.
* :mod:`repro.runtime.router`    -- kernel-ID <-> mesh-coordinate mapping
  and link classification (same-chip / intra-pod ICI / inter-pod DCN);
  the analogue of libGalapagos' router thread.
"""

from repro.runtime.topology import ClusterSpec, make_mesh, make_cpu_mesh
from repro.runtime.transport import (Transport, LossyTransport, TCP, UDP,
                                     LinkClass, default_link_of, is_lossy,
                                     model_latency_s, model_throughput_Bps)
from repro.runtime.router import Router

__all__ = [
    "ClusterSpec",
    "make_mesh",
    "make_cpu_mesh",
    "Transport",
    "LossyTransport",
    "TCP",
    "UDP",
    "LinkClass",
    "default_link_of",
    "is_lossy",
    "model_latency_s",
    "model_throughput_Bps",
    "Router",
]
