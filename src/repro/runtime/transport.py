"""Transports: the Galapagos middleware-layer analogue.

The paper's middleware lets an application switch between TCP, UDP and
raw Ethernet without source changes (Sec. II-B2), and its AM layer marks
messages *asynchronous* to suppress the automatic reply (Sec. III-A):

* ``TCP``  -> *acked* delivery: every AM triggers an automatic reply
  that bumps a credit counter at the source (2 link traversals).
* ``UDP``  -> *async* delivery: fire-and-forget (1 link traversal).

Links are NOT uniformly lossless.  Intra-chip (LOCAL) and intra-pod
(ICI) traffic is reliable, but the DCN link class crosses a real
data-center network where packets drop, duplicate, and bit-corrupt —
and the paper's raw-Ethernet/UDP configurations never promised delivery
in the first place.  :class:`LossyTransport` makes that explicit: it
carries a seedable :class:`repro.core.faults.FaultModel` applied per
link class at the ppermute boundary, and a retransmit bound.  On a
lossy transport the op layer seals every packet with the header CRC
word, stamps a send epoch, and drives acked puts through a bounded
retransmit loop: a drop (or a CRC-failed corruption) suppresses the
ack, the sender re-sends, and the receiver's dedup ledger keyed on
(token, epoch, seq) makes redelivery idempotent.  Senders that exhaust
``max_retries`` latch the sticky ``ERR_RETRY_EXHAUSTED`` error bit
instead of hanging.

A transport also carries the maximum packet size.  The paper inherits a
9000-byte jumbo-frame limit from the hardware TCP core and leaves
segmentation of larger AMs as future work (footnote 2); we implement
that segmentation in :mod:`repro.core.ops`, governed by
``max_packet_bytes`` here.

Finally the transport holds the per-link-class performance model used by
the latency/throughput microbenchmarks to report TPU-target numbers next
to the CPU-host measurements (this container has no ICI).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable


class LinkClass(enum.Enum):
    """The three placement classes of the paper's six topologies.

    Paper (FPGA cluster)              -> TPU pod
    same node (internal routing)      -> LOCAL (same chip, no collective)
    different nodes, HW fast path     -> ICI (intra-pod torus link)
    different nodes via full stack    -> DCN (inter-pod data-center network)
    """

    LOCAL = 0
    ICI = 1
    DCN = 2


@dataclasses.dataclass(frozen=True)
class Transport:
    """Delivery semantics + packet limits + link performance model."""

    name: str
    acked: bool                      # TCP-like auto-reply vs UDP-like async
    max_packet_bytes: int = 9000     # jumbo frame, as in the paper
    word_bytes: int = 4              # one Shoal word = one f32/int32

    # Per-link-class latency (s) and bandwidth (B/s) for the analytic
    # model.  ICI/DCN numbers are TPU-v5e-class; LOCAL models an on-chip
    # HBM copy.
    lat_s: tuple[float, float, float] = (0.2e-6, 1.0e-6, 10.0e-6)
    bw_Bps: tuple[float, float, float] = (819e9, 50e9, 25e9)

    @property
    def max_packet_words(self) -> int:
        return self.max_packet_bytes // self.word_bytes

    def hops_per_message(self) -> int:
        """Link traversals per AM: 1 for the message, +1 for the reply."""
        return 2 if self.acked else 1


TCP = Transport(name="tcp", acked=True)
UDP = Transport(name="udp", acked=False)


def default_link_of(src: int, dst: int) -> LinkClass:
    """Pessimistic default placement: same kernel id = LOCAL, everything
    else crosses the data-center network.  Meshes with a real topology
    map can pass a custom classifier to :class:`LossyTransport`."""
    return LinkClass.LOCAL if src == dst else LinkClass.DCN


@dataclasses.dataclass(frozen=True)
class LossyTransport(Transport):
    """A transport whose lossy link classes drop/duplicate/corrupt.

    ``faults`` is the seedable fault process applied to every link whose
    :class:`LinkClass` is in ``lossy_links`` (default: only DCN —
    LOCAL and ICI stay reliable); ``link_of(src, dst)`` classifies a
    link at trace time.  On an *acked* lossy transport the op layer runs
    reliable puts: CRC-sealed packets, receiver-side dedup, and up to
    ``max_retries`` retransmissions driven by the missing ack before
    latching ``ERR_RETRY_EXHAUSTED``.  On an async lossy transport
    messages stay fire-and-forget — losses are simply losses, exactly
    like the paper's UDP/raw-Ethernet configurations.
    """

    name: str = "lossy-tcp"
    acked: bool = True
    faults: "FaultModel" = None  # required; keyword-only in practice
    lossy_links: tuple[LinkClass, ...] = (LinkClass.DCN,)
    link_of: Callable[[int, int], LinkClass] = default_link_of
    max_retries: int = 4

    def __post_init__(self):
        if self.faults is None:
            raise ValueError("LossyTransport needs a FaultModel "
                             "(use faults=FaultModel(...))")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    def link_is_lossy(self, src: int, dst: int) -> bool:
        return self.link_of(src, dst) in self.lossy_links

    def probs_for(self, src: int, dst: int) -> tuple[float, float, float]:
        """(drop, dup, corrupt) probabilities of the (src, dst) link."""
        if self.link_is_lossy(src, dst):
            return (self.faults.drop, self.faults.dup, self.faults.corrupt)
        return (0.0, 0.0, 0.0)


def is_lossy(transport: Transport) -> bool:
    """Does this transport carry a fault model the op layer must defend
    against?  (A LossyTransport whose model is all-zero is lossless.)"""
    return (isinstance(transport, LossyTransport)
            and not transport.faults.lossless)


def model_latency_s(
    transport: Transport,
    link: LinkClass,
    payload_bytes: int,
    header_bytes: int = 64,
    hops: int | None = None,
) -> float:
    """Analytic end-to-end latency of one AM (plus reply if acked).

    latency = hops * (link latency + message bytes / link bandwidth)
    where the reply is a header-only Short AM.
    """
    i = link.value
    lat, bw = transport.lat_s[i], transport.bw_Bps[i]
    fwd = lat + (header_bytes + payload_bytes) / bw
    if hops is not None:
        return hops * fwd
    if transport.acked:
        rep = lat + header_bytes / bw
        return fwd + rep
    return fwd


def model_throughput_Bps(
    transport: Transport, link: LinkClass, payload_bytes: int, header_bytes: int = 64
) -> float:
    """Sustained payload throughput of back-to-back pipelined AMs: the
    wire carries header+payload, only payload counts as goodput.  Replies
    flow on the reverse link and do not consume forward bandwidth."""
    i = link.value
    eff = transport.bw_Bps[i] * payload_bytes / (payload_bytes + header_bytes)
    return eff
