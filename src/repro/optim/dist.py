"""Distributed-optimization tricks.

* **int8 error-feedback gradient compression** for the thin inter-pod
  (DCN) link: grads are quantized per-tensor to int8 before the pod-axis
  reduction; the quantization residual is fed back into the next step's
  grads so the *accumulated* error stays bounded (1-bit/‖EF‖ literature;
  here 8-bit).  4x fewer bytes on the pod axis — the collective-term
  lever for multi-pod training (EXPERIMENTS.md §Perf).
* **ZeRO-1 optimizer-state sharding**: AdamW m/v are sharded over the DP
  axis along the first divisible dimension — 1/N_dp the optimizer-state
  HBM at the cost of (already-needed) grad reduce-scatter locality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress_int8(x):
    """x -> (int8 q, f32 scale); symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def make_error_feedback(params):
    """Zero residual buffers, one per grad leaf."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, residual):
    """(grads + residual) -> (quantized tree, new residual)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = compress_int8(g)
        back = decompress_int8(q, s)
        return (q, s), g - back

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    qs, res = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    return treedef.unflatten(list(qs)), treedef.unflatten(list(res))


def ef_decompress_tree(qtree, dtype=jnp.float32):
    return jax.tree.map(lambda qs: decompress_int8(qs[0], qs[1], dtype), qtree,
                        is_leaf=lambda x: isinstance(x, tuple))


def zero1_pspecs(param_pspecs, dp_axis: str, params, axis_size: int = 1):
    """Shard optimizer state over ``dp_axis`` along the first dim that is
    unsharded in the param spec and divisible by the axis size.  Falls
    back to the param's own spec (replication over DP)."""

    def one(spec: P, p):
        t = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
        used = {a for s in t if s for a in (s if isinstance(s, tuple) else (s,))}
        if dp_axis in used:
            return P(*t)
        for i, s in enumerate(t):
            if s is None and axis_size > 1 and p.shape[i] % axis_size == 0:
                lst = list(t)
                lst[i] = dp_axis
                return P(*lst)
        return P(*t)

    return jax.tree.map(one, param_pspecs, params,
                        is_leaf=lambda x: isinstance(x, P))
