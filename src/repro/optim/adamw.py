"""AdamW with decoupled weight decay and global-norm gradient clipping.

Functional, pytree-generic, f32 optimizer state (m, v) regardless of the
compute dtype; ZeRO-1 sharding of (m, v) is expressed through
:func:`repro.optim.dist.zero1_pspecs`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
