from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.optim.dist import (
    compress_int8, decompress_int8, make_error_feedback, zero1_pspecs,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine",
    "compress_int8", "decompress_int8", "make_error_feedback", "zero1_pspecs",
]
