"""Deterministic, checkpointable, sharded data pipeline.

Design requirements at pod scale:

* **Determinism & restart**: the pipeline state is a single integer
  (the step counter) carried inside the checkpoint, and batch contents
  are a pure function of (seed, step) via counter-based Philox streams —
  restoring a checkpoint replays no sample and skips none.
* **Sharding**: batches are produced host-side then ``device_put`` with
  the batch PartitionSpec; at real pod scale each host would generate
  only its slice (the generator is indexed by global batch row, so the
  slice is well-defined per host — see ``rows()``).
* **Modalities**: token streams (zipf-mixture synthetic LM data), an
  embeddings frontend for the audio stub, and image-feature stubs for
  the VLM.  A memmap-backed file source covers the "real corpus" path.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    kind: str = "tokens"          # tokens | embeddings
    d_model: int = 0              # for embeddings kind
    image_tokens: int = 0         # >0 adds image_feats (VLM stub)
    zipf_a: float = 1.2           # synthetic token distribution
    corpus: str | None = None     # optional memmap token file


class TokenPipeline:
    """state = step counter; ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._corpus = None
        if cfg.corpus:
            self._corpus = np.memmap(cfg.corpus, dtype=np.int32, mode="r")

    def init_state(self) -> int:
        return 0

    def rows(self, step: int, lo: int = 0, hi: int | None = None):
        """Generate batch rows [lo, hi) — the per-host slice at scale."""
        cfg = self.cfg
        hi = cfg.batch if hi is None else hi
        out_tok = np.empty((hi - lo, cfg.seq + 1), np.int32)
        for r in range(lo, hi):
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed, counter=[0, 0, step, r]))
            if self._corpus is not None:
                start = int(rng.integers(0, max(1, self._corpus.size - cfg.seq - 1)))
                out_tok[r - lo] = np.asarray(
                    self._corpus[start:start + cfg.seq + 1]) % cfg.vocab
            else:
                z = rng.zipf(cfg.zipf_a, size=cfg.seq + 1)
                out_tok[r - lo] = np.minimum(z, cfg.vocab - 1).astype(np.int32)
        return out_tok

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        tok = self.rows(step)
        batch: dict[str, np.ndarray] = {
            "labels": tok[:, 1:].astype(np.int32),
        }
        if cfg.kind == "embeddings":
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed + 1, counter=[0, 0, step, 0]))
            batch["embeddings"] = rng.standard_normal(
                (cfg.batch, cfg.seq, cfg.d_model), np.float32) * 0.02
        else:
            batch["tokens"] = tok[:, :-1].astype(np.int32)
        if cfg.image_tokens:
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed + 2, counter=[0, 0, step, 0]))
            batch["image_feats"] = rng.standard_normal(
                (cfg.batch, cfg.image_tokens, cfg.d_model), np.float32) * 0.02
        return batch

    def next_batch(self, state: int, shardings=None):
        """(state) -> (device batch, state+1)."""
        host = self.batch_at(state)
        if shardings is None:
            dev = {k: jax.numpy.asarray(v) for k, v in host.items()}
        else:
            dev = {k: jax.device_put(v, shardings.get(k)) for k, v in host.items()}
        return dev, state + 1


def write_synthetic_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0):
    """A tiny on-disk corpus for the file-backed path (tests/examples)."""
    rng = np.random.Generator(np.random.Philox(key=seed))
    arr = np.minimum(rng.zipf(1.2, size=n_tokens), vocab - 1).astype(np.int32)
    arr.tofile(path)
    return path
