import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: JAX locks the
# device count at first init, and the production meshes below need 512
# placeholder host devices (dry-run only — no tensor is ever allocated).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the real train_step / prefill / decode_step,
  3. ``jit(...).lower(**ShapeDtypeStruct args).compile()`` — proving the
     sharding config is coherent at 512 chips,
  4. records memory_analysis / cost_analysis / trip-count-weighted
     collective bytes (launch/hlo_analysis.py) to a JSON lines file that
     §Roofline and §Perf read.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.configs import shapes as shp
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.training.train import Trainer, TrainerConfig


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             comm_backend: str = "xla", override_cfg=None,
             save_hlo: str | None = None, microbatches: int = 8,
             serve_tp_only: bool = False) -> dict:
    """``serve_tp_only``: serve-path weights sharded TP-only (no FSDP) —
    inference wants gathered-once weights, not per-layer FSDP gathers."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes_of(mesh)
    cfg = override_cfg if override_cfg is not None else configs.full(arch)
    if not cfg.tp and not cfg.seq_shard:
        # no tensor parallelism: the model axis joins DP (with seq_shard
        # the model axis carries the sequence instead)
        dp = dp + ("model",)
    shape = shp.SHAPES[shape_name]
    if not shp.applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md Sec. 5)"}

    if serve_tp_only and shape.mode in ("prefill", "decode"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, fsdp=False)
    # the shoal backend runs the model inside a manual-DP shard_map, so
    # its activation constraints must not mention the DP axes
    model_dp = () if comm_backend == "shoal" else dp
    model = build_model(cfg, mesh=mesh, dp_axes=model_dp)
    t0 = time.time()
    scan_trips = [reps for _, reps in cfg.segments()]

    if shape.mode == "train":
        trainer = Trainer(model, AdamWConfig(),
                          TrainerConfig(comm_backend=comm_backend,
                                        microbatches=microbatches),
                          dp_axes=dp)
        state_sds, batch_sds = specs.train_args(model, trainer, shape, mesh)
        step = trainer.make_train_step()
        lowered = step.lower(state_sds, batch_sds)
    elif shape.mode == "prefill":
        params, batch, cache = specs.prefill_args(model, shape, mesh)
        lowered = jax.jit(model.prefill, donate_argnums=(2,)).lower(
            params, batch, cache)
    else:  # decode
        params, cache, token, pos = specs.decode_args(model, shape, mesh)
        if cfg.family == "vlm":
            from jax.sharding import NamedSharding, PartitionSpec as P
            imf = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_image_tokens, cfg.d_model),
                jax.numpy.bfloat16,
                sharding=NamedSharding(mesh, P(dp, None, None)))
            lowered = jax.jit(model.decode_step, donate_argnums=(1,)).lower(
                params, cache, token, pos, imf)
        else:
            lowered = jax.jit(model.decode_step, donate_argnums=(1,)).lower(
                params, cache, token, pos)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    coll = hlo_analysis.parse_collectives(hlo)

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "backend": comm_backend, "mode": shape.mode, "status": "ok",
        "mesh": dict(mesh.shape),
        "scan_trips": scan_trips,
        "microbatches": microbatches if shape.mode == "train" else 0,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": cost.get("flops", 0.0),
            "dot_flops_weighted": coll.dot_flops,
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "output_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)
                           - getattr(mem, "alias_size_in_bytes", 0)),
            "collective_shape_bytes": coll.shape_bytes,
            "collective_wire_bytes": coll.wire_bytes,
            "collective_ops": coll.ops,
            "collective_by_kind": coll.by_kind,
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--backend", default="xla", choices=["xla", "shoal"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()

    cells = []
    archs = ([a.replace("_", "-") for a in configs.ARCH_IDS]
             if args.all or args.arch is None else [args.arch])
    shapes = (list(shp.SHAPES) if args.all or args.shape is None
              else [args.shape])
    pods = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in pods:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        label = f"{a} x {s} x {'2pod' if mp else '1pod'} [{args.backend}]"
        try:
            rec = run_cell(a, s, multi_pod=mp, comm_backend=args.backend,
                           save_hlo=args.save_hlo,
                           microbatches=args.microbatches)
        except Exception as e:  # a failing cell is a bug in the system
            rec = {"arch": a, "shape": s, "multi_pod": mp,
                   "backend": args.backend, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        if rec["status"] == "ok":
            n_ok += 1
            pd = rec["per_device"]
            print(f"OK   {label}: compile {rec['compile_s']}s, "
                  f"{pd['flops']/1e9:.1f} GF/dev, "
                  f"peak {pd['peak_bytes']/1e9:.2f} GB/dev, "
                  f"wire {pd['collective_wire_bytes']/1e6:.1f} MB/dev",
                  flush=True)
        elif rec["status"] == "skipped":
            n_skip += 1
            print(f"SKIP {label}: {rec['reason']}", flush=True)
        else:
            n_fail += 1
            print(f"FAIL {label}: {rec['error']}", flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
