"""Post-optimization HLO analysis: collective bytes, trip-count-aware.

``compiled.cost_analysis()`` gives FLOPs and memory traffic but not
collective traffic, so we parse ``compiled.as_text()``:

* every ``all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute`` op contributes its shape bytes;
* ops inside ``while`` bodies (scan-over-layers!) are multiplied by the
  loop trip count, recovered from the loop-condition computation's
  ``compare(..., constant(K))`` pattern — models here scan over layer
  segments, so this weighting is what makes per-step totals correct;
* *wire* bytes additionally weight each op by its algorithmic transfer
  factor on a ring (all-reduce moves 2(n-1)/n bytes/byte, all-gather and
  reduce-scatter (n-1)/n, all-to-all (n-1)/n, collective-permute 1).

Group size is parsed from ``replica_groups={{...}}`` or the iota form
``replica_groups=[G,N]<=[...]``.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\]"
    r"[^=]*?\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,?\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_KNOWN_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_CALL_RE = re.compile(
    r"(?:call|fusion)\(.*?(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


@dataclasses.dataclass
class CollectiveStats:
    ops: dict                    # kind -> count (trip-weighted)
    shape_bytes: float           # trip-weighted sum of output-shape bytes
    wire_bytes: float            # ring-model wire traffic per device
    by_kind: dict                # kind -> wire bytes
    dot_flops: float = 0.0       # trip-weighted matmul FLOPs per device


_LHS_SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*\bdot\(\s*%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = m.group(1)
                comps[cur] = []
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Largest compare-constant in the condition: scans compare the
    induction variable against the trip count."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_CMP_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = split_computations(hlo)

    # weight of each computation = product of enclosing trip counts
    weights: dict[str, float] = {}

    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
    if entry is None and comps:
        entry = next(iter(comps))

    def visit(name: str, w: float, depth=0):
        if name not in comps or depth > 32:
            return
        weights[name] = weights.get(name, 0.0) + w
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                km = _KNOWN_TRIP_RE.search(line)
                trips = (int(km.group(1)) if km
                         else _trip_count(comps.get(cond, [])))
                visit(body, w * trips, depth + 1)
                visit(cond, w * trips, depth + 1)
            else:
                cm = _CALL_RE.search(line)
                if cm:
                    visit(cm.group(1), w, depth + 1)

    if entry:
        visit(entry, 1.0)

    ops: dict[str, float] = {}
    shape_bytes = 0.0
    wire = 0.0
    dot_flops = 0.0
    by_kind: dict[str, float] = {}
    seen_started: set[str] = set()
    for name, lines in comps.items():
        w = weights.get(name, 1.0 if name == entry else 0.0)
        if w == 0.0:
            continue
        # per-computation symbol table: op name -> dims (for dot operands)
        symtab: dict[str, list[int]] = {}
        for line in lines:
            sm = _LHS_SHAPE_RE.match(line)
            if sm:
                symtab[sm.group(1)] = [int(d) for d in sm.group(3).split(",") if d]
        for line in lines:
            dm = _DOT_RE.search(line)
            if dm:
                out_dims = [int(d) for d in dm.group(2).split(",") if d]
                lhs_name = dm.group(3)
                cm = _LHS_CDIMS_RE.search(line)
                csize = 1
                if cm and lhs_name in symtab:
                    lhs_dims = symtab[lhs_name]
                    for ci in cm.group(1).split(","):
                        if ci:
                            csize *= lhs_dims[int(ci)]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                dot_flops += w * 2.0 * out_n * csize
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            opname, dtype, dims, kind = m.groups()
            if opname.endswith(".done") or "-done" in line.split("=")[1][:40]:
                # async pairs: count the start only
                if opname in seen_started:
                    continue
            seen_started.add(opname)
            b = _shape_bytes(dtype, dims)
            # tuple shapes: sum all components
            lhs = line.split("=", 1)[1]
            if lhs.strip().startswith("("):
                b = sum(_shape_bytes(d, s) for d, s in
                        _TUPLE_SHAPE_RE.findall(lhs.split(")")[0]))
            gm = _GROUPS_BRACE_RE.search(line)
            if gm:
                n = len(gm.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                n = int(gi.group(2)) if gi else 2
            ops[kind] = ops.get(kind, 0.0) + w
            shape_bytes += w * b
            wb = w * b * _wire_factor(kind, n)
            wire += wb
            by_kind[kind] = by_kind.get(kind, 0.0) + wb
    return CollectiveStats(ops=ops, shape_bytes=shape_bytes,
                           wire_bytes=wire, by_kind=by_kind,
                           dot_flops=dot_flops)
