"""Production mesh definitions (TPU v5e pods).

A function, not a module-level constant: importing this module must
never touch JAX device state (the dry-run sets the host-device-count
flag before first JAX init).
"""

from __future__ import annotations

from repro.runtime.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods over DCN when ``multi_pod``.

    Axes: ``data`` = batch parallelism (+FSDP weight sharding for the
    large configs), ``model`` = tensor/expert parallelism, ``pod`` = the
    DCN axis (stacked onto data parallelism by the trainer).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
