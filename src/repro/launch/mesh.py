"""Production mesh definitions (TPU v5e pods).

A function, not a module-level constant: importing this module must
never touch JAX device state (the dry-run sets the host-device-count
flag before first JAX init).
"""

from __future__ import annotations

import dataclasses

from repro.runtime.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods over DCN when ``multi_pod``.

    Axes: ``data`` = batch parallelism (+FSDP weight sharding for the
    large configs), ``model`` = tensor/expert parallelism, ``pod`` = the
    DCN axis (stacked onto data parallelism by the trainer).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# --------------------------------------------------------------------------
# disaggregated-serving slices
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingSlices:
    """Prefill/decode split of one kernel axis for disaggregated serving.

    The first ``n_prefill`` kernel IDs form the prefill slice, the next
    ``n_decode`` the decode slice; both live on ONE mesh so a finished
    prefill's KV migrates decode-ward as a single one-sided vectored put
    along ``migration_pattern`` (no gather/scatter collective, no
    cross-mesh transfer).
    """

    n_prefill: int
    n_decode: int
    axis: str = "kernel"

    def __post_init__(self):
        if self.n_prefill < 1 or self.n_decode < 1:
            raise ValueError(
                f"serving slices need >= 1 kernel each, got "
                f"prefill={self.n_prefill} decode={self.n_decode}")

    @property
    def num_kernels(self) -> int:
        return self.n_prefill + self.n_decode

    @property
    def prefill_ids(self) -> tuple[int, ...]:
        return tuple(range(self.n_prefill))

    @property
    def decode_ids(self) -> tuple[int, ...]:
        return tuple(range(self.n_prefill, self.n_prefill + self.n_decode))

    def role_of(self, kernel: int) -> str:
        if kernel in self.prefill_ids:
            return "prefill"
        if kernel in self.decode_ids:
            return "decode"
        raise ValueError(f"kernel {kernel} outside the serving mesh "
                         f"({self.num_kernels} kernels)")

    def migration_pattern(self, prefill: int, decode: int):
        """The static ``[(src, dst)]`` a finished prefill's KV rides."""
        if prefill not in self.prefill_ids:
            raise ValueError(f"kernel {prefill} is not in the prefill "
                             f"slice {self.prefill_ids}")
        if decode not in self.decode_ids:
            raise ValueError(f"kernel {decode} is not in the decode "
                             f"slice {self.decode_ids}")
        return [(prefill, decode)]


def make_serving_mesh(slices: ServingSlices):
    """One 1-D kernel mesh spanning both slices (prefill IDs first)."""
    return make_mesh((slices.num_kernels,), (slices.axis,))
