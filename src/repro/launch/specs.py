"""ShapeDtypeStruct stand-ins for every model input (no allocation).

The dry-run lowers real step functions against these: weak-type-correct,
sharding-annotated, zero device memory.  Serve-path params are bf16
(inference checkpoints); train-path params are f32 masters inside the
TrainState.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.training.train import Trainer


def _sds(tree, shardings=None):
    def one(x, s=None):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
    if shardings is None:
        return jax.tree.map(one, tree)
    return jax.tree.map(one, tree, shardings)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _in_sds(model: Model, mesh, shape_t, dtype, spec: P):
    """SDS with a divisibility-sanitized sharding (argument shardings,
    unlike constraints, must divide evenly — long_500k has batch 1)."""
    spec = model._sanitize(spec, shape_t)
    return jax.ShapeDtypeStruct(shape_t, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(model: Model, shape: ShapeSpec, mesh, dp=None):
    """Training/prefill batch ShapeDtypeStructs with DP sharding."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    dp = model.dp_axes if dp is None else dp
    seq = model._seq_axis()   # model axis when cfg.seq_shard, else None
    out = {}
    if cfg.frontend == "embeddings":
        out["embeddings"] = _in_sds(model, mesh, (B, S, cfg.d_model),
                                    jnp.bfloat16, P(dp, seq, None))
    else:
        out["tokens"] = _in_sds(model, mesh, (B, S), jnp.int32, P(dp, seq))
    if shape.mode == "train":
        out["labels"] = _in_sds(model, mesh, (B, S), jnp.int32, P(dp, seq))
    if cfg.family == "vlm":
        out["image_feats"] = _in_sds(model, mesh,
                                     (B, cfg.n_image_tokens, cfg.d_model),
                                     jnp.bfloat16, P(dp, None, None))
    return out


def train_args(model: Model, trainer: Trainer, shape: ShapeSpec, mesh):
    """(state_sds, batch_sds) for jit(train_step).lower."""
    state_shape = jax.eval_shape(trainer.init_state, jax.random.PRNGKey(0))
    shardings = trainer.state_shardings(state_shape)
    state_sds = _sds(state_shape, shardings)
    return state_sds, batch_specs(model, shape, mesh, dp=trainer.dp_axes)


def _cache_pspec(path, leaf, dp, m, seq=None, slot_shard=False) -> P:
    name = str(getattr(path[-1], "key", path[-1]))
    nd = leaf.ndim              # includes leading segment-stack dim
    if name in ("k", "v"):       # (reps,B,W,K,dh)
        if seq is not None or slot_shard:
            # slots over the model axis: decode attention then runs a
            # partial softmax per shard and combines with tiny psums —
            # measured 700x less decode wire than dh-sharding (§Perf)
            return P(None, dp, seq or m, None, None)
        return P(None, dp, None, None, m)
    if name == "pos" and (seq is not None or slot_shard):
        return P(None, dp, seq or m)
    if name in ("ckv", "kr"):    # (reps,B,W,c)
        if slot_shard:
            return P(None, dp, m, None)
        return P(None, dp, None, m)
    if name == "C":              # (reps,B,nh,dh,dh)
        return P(None, dp, None, m, None)
    if name == "n" and nd == 4:  # (reps,B,nh,dh)
        return P(None, dp, None, m)
    if name == "conv" and nd == 4:
        return P(None, dp, None, m)
    if name in ("h", "c", "n", "m") and nd == 3:
        return P(None, dp, m)
    if name == "pos":
        return P(None, dp, None)
    if name == "m" and nd == 3:
        return P(None, dp, None)
    return P(*((None,) * nd))


def serve_params_sds(model: Model, mesh):
    """bf16 inference params with the model's PartitionSpecs."""
    p_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = model.param_pspecs(p_shape)
    shardings = _named(mesh, pspecs)

    def one(x, s):
        dt = jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt, sharding=s)

    return jax.tree.map(one, p_shape, shardings)


def cache_sds(model: Model, B: int, slots: int, mesh, slot_shard=False):
    cache_shape = jax.eval_shape(
        lambda: model.make_cache(B, slots))
    dp = model.dp_axes

    def one(path, x):
        spec = model._sanitize(
            _cache_pspec(path, x, dp, model.model_axis,
                         seq=model._seq_axis(), slot_shard=slot_shard),
            x.shape)
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def decode_args(model: Model, shape: ShapeSpec, mesh):
    """(params, cache, token, pos) SDS for jit(decode_step).lower."""
    cfg = model.cfg
    B = shape.global_batch
    dp = model.dp_axes
    params = serve_params_sds(model, mesh)
    cache = cache_sds(model, B, shape.seq_len, mesh, slot_shard=True)
    if cfg.frontend == "embeddings":
        token = _in_sds(model, mesh, (B, 1, cfg.d_model), jnp.bfloat16,
                        P(dp, None, None))
    else:
        token = _in_sds(model, mesh, (B, 1), jnp.int32, P(dp, None))
    pos = _in_sds(model, mesh, (B,), jnp.int32, P(dp))
    return params, cache, token, pos


def prefill_args(model: Model, shape: ShapeSpec, mesh):
    """(params, batch, cache) SDS for jit(prefill).lower.  Window archs
    allocate only window-deep kv slots (handled by make_cache)."""
    params = serve_params_sds(model, mesh)
    batch = batch_specs(model, shape, mesh)
    cache = cache_sds(model, shape.global_batch, shape.seq_len, mesh)
    return params, batch, cache
