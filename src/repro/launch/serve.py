"""Serving launcher: batched requests through the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 6 --lanes 2
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.full(args.arch)
    if cfg.frontend != "tokens":
        raise SystemExit("serving demo supports token-frontend archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, lanes=args.lanes, slots=args.slots)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        rng.integers(3, 10)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    for r in done:
        print(f"req {r.rid}: prompt {list(r.prompt)} -> {r.out}")
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on CPU, {args.lanes} lanes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
