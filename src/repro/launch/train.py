"""Production training launcher: checkpointed, fault-tolerant step loop.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised end-to-end: data pipeline state in the checkpoint,
async checkpointing off the critical path, automatic restore-on-restart
(re-running the same command resumes), retry-on-failure with bounded
restarts, comm-backend selection, and the latency-hiding scheduler flags
a real pod deployment would set.
"""

import argparse
import os
import sys
import time

# compute/comm overlap: enable XLA's latency-hiding scheduler for
# collectives (harmless on CPU; the production win on pods)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_tpu_enable_latency_hiding_scheduler=true"
    if "tpu" in os.environ.get("JAX_PLATFORMS", "")
    else os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.training.elastic import FailureInjector
from repro.training.train import Trainer, TrainerConfig


def make_parts(args):
    cfg = (configs.reduced(args.arch) if args.reduced
           else configs.full(args.arch))
    model = build_model(cfg)
    opt = AdamWConfig(lr=warmup_cosine(args.lr, args.warmup, args.steps))
    trainer = Trainer(model, opt,
                      TrainerConfig(comm_backend=args.backend,
                                    microbatches=args.microbatches,
                                    donate=False))
    dcfg = DataConfig(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed,
        kind="embeddings" if cfg.frontend == "embeddings" else "tokens",
        d_model=cfg.d_model,
        image_tokens=cfg.n_image_tokens if cfg.family == "vlm" else 0)
    pipe = TokenPipeline(dcfg)
    return cfg, model, trainer, pipe


def train_once(args, injector=None):
    """One launcher attempt: restore if possible, run to args.steps."""
    cfg, model, trainer, pipe = make_parts(args)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    step_fn = trainer.make_train_step()

    state = trainer.init_state(jax.random.PRNGKey(args.seed))
    dstep = pipe.init_state()
    if mgr.latest_step() is not None:
        state, extras = mgr.restore(state)
        dstep = extras["data_step"]
        print(f"[launch] restored step {int(state.step)} "
              f"(data step {dstep})", flush=True)

    t_last = time.time()
    while int(state.step) < args.steps:
        if injector is not None:
            injector.check(int(state.step))
        batch, dstep = pipe.next_batch(dstep)
        state, metrics = step_fn(state, batch)
        s = int(state.step)
        if s % args.log_every == 0:
            dt = time.time() - t_last
            t_last = time.time()
            print(f"[train] step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt / args.log_every:.2f}s/step)", flush=True)
        if s % args.ckpt_every == 0:
            mgr.save_async(s, state, extras={"data_step": dstep})
    mgr.wait()
    mgr.save(int(state.step), state, extras={"data_step": dstep})
    return state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="xla", choices=["xla", "shoal"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (fault-tolerance demo)")
    args = ap.parse_args(argv)

    injector = FailureInjector(set(args.fail_at)) if args.fail_at else None
    for attempt in range(args.max_restarts + 1):
        try:
            state = train_once(args, injector)
            print(f"[launch] done at step {int(state.step)}")
            return 0
        except RuntimeError as e:   # node failure
            print(f"[launch] attempt {attempt} failed: {e}; restarting "
                  f"from last checkpoint", flush=True)
    print("[launch] exceeded max restarts", file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
