from repro.apps.jacobi import JacobiApp

__all__ = ["JacobiApp"]
