"""The Jacobi stencil application on Shoal (paper Sec. IV-C).

The grid (N x N) is row-partitioned over kernels.  Each iteration:

  1. every kernel one-sided-puts its first/last owned row into its
     neighbors' halo slots (Shoal Long puts — *not* send/recv pairs;
     boundary kernels simply aren't in the pattern),
  2. waits for its own halos' replies (wait_replies = GASNet quiet),
  3. runs the von Neumann stencil over its band (optionally the Pallas
     kernel from :mod:`repro.kernels.jacobi`).

Segment layout per kernel: [0, N) = top halo row, [N, 2N) = bottom halo.

The paper's footnote-2 limitation — at grid 4096 a halo row exceeds the
9000-byte jumbo frame and their runs *fail* — is handled here by the
transparent >MTU segmentation in :func:`repro.core.ops.put_long`; the
benchmark runs exactly that configuration.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.runtime.jax_compat import shard_map
import numpy as np

from repro.core import handlers as hd
from repro.core import ops
from repro.core.gascore import dataclasses_replace
from repro.core.state import PgasState, ShoalContext
from repro.runtime import TCP
from repro.runtime.topology import make_cpu_mesh


@dataclasses.dataclass
class JacobiApp:
    n: int                    # grid is n x n
    kernels: int
    iters: int
    transport: object = TCP
    use_pallas: bool = False

    def __post_init__(self):
        assert self.n % self.kernels == 0
        self.rows = self.n // self.kernels
        self.mesh = make_cpu_mesh(self.kernels, ("kernel",))
        self.ctx = ShoalContext(mesh=self.mesh, axes=("kernel",),
                                transport=self.transport,
                                segment_words=2 * self.n)
        k = self.kernels
        self.up = [(i, i - 1) for i in range(1, k)]      # send top row up
        self.down = [(i, i + 1) for i in range(k - 1)]   # send bottom row down

    # -- one iteration (runs inside shard_map) --------------------------------

    def _halo_exchange(self, st: PgasState, block: jnp.ndarray) -> PgasState:
        n = self.n
        if self.kernels == 1:
            return st
        # my top row -> upper neighbor's *bottom* halo [n, 2n)
        st = ops.put_long(self.ctx, st, block[0], self.up, dst_addr=n,
                          handler=hd.H_WRITE, token=1)
        # my bottom row -> lower neighbor's *top* halo [0, n)
        st = ops.put_long(self.ctx, st, block[-1], self.down, dst_addr=0,
                          handler=hd.H_WRITE, token=2)
        if self.transport.acked:
            # Replies coalesce across >MTU segmentation (only the final
            # packet of a halo row is acked), so each halo *message*
            # earns exactly one credit regardless of how many packets
            # the transport split it into.
            me = self.ctx.my_id()
            has_down = (me < self.kernels - 1).astype(jnp.int32)
            has_up = (me > 0).astype(jnp.int32)
            # replies for token 1 come from puts I sent up, etc.
            st = ops.wait_replies(self.ctx, st, 1, has_up)
            st = ops.wait_replies(self.ctx, st, 2, has_down)
        return st

    def _stencil(self, block_pad: jnp.ndarray, kid) -> jnp.ndarray:
        """block_pad: (rows+2, n) with halo rows attached.  (The Pallas
        variant of this loop is benchmarked separately in
        benchmarks/bench_utilization.py; on the CPU host the jnp form is
        what XLA vectorizes best, mirroring the paper's SW/HW split.)"""
        up = block_pad[:-2]
        down = block_pad[2:]
        mid = block_pad[1:-1]
        left = jnp.pad(mid[:, :-1], ((0, 0), (1, 0)))
        right = jnp.pad(mid[:, 1:], ((0, 0), (0, 1)))
        stencil = 0.25 * (up + down + left + right)
        rows, n = mid.shape
        grow = kid * rows + jax.lax.broadcasted_iota(jnp.int32, (rows, n), 0)
        gcol = jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1)
        interior = ((grow > 0) & (grow < self.n - 1)
                    & (gcol > 0) & (gcol < n - 1))
        return jnp.where(interior, stencil.astype(mid.dtype), mid)

    def _iteration(self, st: PgasState, block: jnp.ndarray):
        n = self.n
        kid = self.ctx.my_id()
        st = self._halo_exchange(st, block)
        top_halo = st.segment[:n]
        bot_halo = st.segment[n:2 * n]
        # boundary kernels have no halo: use zero rows (masked anyway)
        top = jnp.where(kid > 0, top_halo, 0.0)
        bot = jnp.where(kid < self.kernels - 1, bot_halo, 0.0)
        pad = jnp.concatenate([top[None], block, bot[None]], axis=0)
        block = self._stencil(pad, kid)
        st = ops.barrier(self.ctx, st)
        return st, block

    # -- host-level driver ------------------------------------------------------

    def build(self):
        """Returns a jitted function (grid_blocks) -> grid_blocks running
        all iterations; grid_blocks: (kernels, rows, n) sharded."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        ctx = self.ctx

        def per_kernel(st, block):
            st = jax.tree.map(lambda x: x[0], st)
            block = block[0]

            def body(carry, _):
                st, blk = carry
                st, blk = self._iteration(st, blk)
                return (st, blk), ()

            (st, block), _ = jax.lax.scan(body, (st, block), None,
                                          length=self.iters)
            return (jax.tree.map(lambda x: x[None], st), block[None])

        spec = P(("kernel",))
        fn = shard_map(per_kernel, mesh=self.mesh,
                           in_specs=(spec, spec), out_specs=(spec, spec))
        return jax.jit(fn)

    def run(self, grid: np.ndarray):
        """Run on a host grid (n, n); returns the final grid."""
        from repro.core.address_space import GlobalAddressSpace

        gas = GlobalAddressSpace(self.ctx)
        st = gas.make_global_state()
        blocks = jnp.asarray(grid.reshape(self.kernels, self.rows, self.n))
        fn = self.build()
        st, out = fn(st, blocks)
        return np.asarray(out).reshape(self.n, self.n)


def jacobi_reference(grid: np.ndarray, iters: int) -> np.ndarray:
    """Single-kernel oracle."""
    from repro.kernels.jacobi.ref import jacobi_step_ref
    x = jnp.asarray(grid)
    step = jax.jit(jacobi_step_ref)
    for _ in range(iters):
        x = step(x)
    return np.asarray(x)
