"""The Jacobi stencil application on Shoal (paper Sec. IV-C).

The grid (N x N) is row-partitioned over kernels.  Each iteration:

  1. every kernel one-sided-puts its first/last owned row into its
     neighbors' halo slots (Shoal Long puts — *not* send/recv pairs;
     boundary kernels simply aren't in the pattern),
  2. waits for its own halos' replies (wait_replies = GASNet quiet),
  3. runs the von Neumann stencil over its band (optionally the Pallas
     kernel from :mod:`repro.kernels.jacobi`).

Segment layout per kernel: [0, N) = top halo row, [N, 2N) = bottom halo.

The paper's footnote-2 limitation — at grid 4096 a halo row exceeds the
9000-byte jumbo frame and their runs *fail* — is handled here by the
transparent >MTU segmentation in :func:`repro.core.ops.put_long_multi`;
the benchmark runs exactly that configuration.

Steady-state wire plan (``piggyback=True``, the default on an acked
transport): both halo puts go through one ``put_long_multi`` call with
``defer_ack`` — the up/down patterns share every interior kernel as a
source, so they cannot merge into one permutation, but neither put
ships a reply collective.  Instead each direction's data packet carries
the *opposite* direction's acks home in its piggyback lane (token 1 =
up puts, token 2 = down puts; the up packet travels the reverse of the
down link, so it piggybacks token 2's acks and vice versa).  That makes
the loop body exactly 2 collective-permutes per iteration — down from 4
— with iteration *k*'s acks arriving on iteration *k+1*'s packets, so
the waits are gated past the first iteration and a pair of
``drain_deferred_acks`` after the loop balances the books.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.runtime.jax_compat import shard_map
import numpy as np

from repro.core import handlers as hd
from repro.core import ops
from repro.core.gascore import dataclasses_replace
from repro.core.state import PgasState, ShoalContext
from repro.runtime import TCP
from repro.runtime.topology import make_cpu_mesh


@dataclasses.dataclass
class JacobiApp:
    n: int                    # grid is n x n
    kernels: int
    iters: int
    transport: object = TCP
    use_pallas: bool = False
    piggyback: bool = True    # defer halo acks onto the next iteration's
                              # reverse-link data packet (acked transports)

    def __post_init__(self):
        assert self.n % self.kernels == 0
        self.rows = self.n // self.kernels
        self.mesh = make_cpu_mesh(self.kernels, ("kernel",))
        self.ctx = ShoalContext(mesh=self.mesh, axes=("kernel",),
                                transport=self.transport,
                                segment_words=2 * self.n)
        k = self.kernels
        self.up = [(i, i - 1) for i in range(1, k)]      # send top row up
        self.down = [(i, i + 1) for i in range(k - 1)]   # send bottom row down

    # -- one iteration (runs inside shard_map) --------------------------------

    @property
    def _use_piggyback(self) -> bool:
        return self.piggyback and self.transport.acked and self.kernels > 1

    def _halo_exchange(self, st: PgasState, block: jnp.ndarray,
                       it=None) -> PgasState:
        n = self.n
        if self.kernels == 1:
            return st
        me = self.ctx.my_id()
        has_down = (me < self.kernels - 1).astype(jnp.int32)
        has_up = (me > 0).astype(jnp.int32)
        # my top row -> upper neighbor's *bottom* halo [n, 2n);
        # my bottom row -> lower neighbor's *top* halo [0, n)
        items = [(block[0], self.up, n), (block[-1], self.down, 0)]
        if self._use_piggyback:
            # Steady state: no reply collectives at all.  Receivers
            # ledger the acks and each direction's data packet carries
            # the OPPOSITE direction's ledgered acks home (the up packet
            # travels the reverse of the down link, so pb_token=2).
            st = ops.put_long_multi(self.ctx, st, items,
                                    handler=hd.H_WRITE, tokens=[1, 2],
                                    defer_ack=True, piggyback_tokens=[2, 1])
            # iteration k's ack rides iteration k+1's packet: wait only
            # from the second iteration on (drain_deferred_acks after
            # the loop balances the final iteration)
            ready = (jnp.asarray(it) > 0).astype(jnp.int32) \
                if it is not None else jnp.zeros((), jnp.int32)
            st = ops.wait_replies(self.ctx, st, 1, has_up * ready)
            st = ops.wait_replies(self.ctx, st, 2, has_down * ready)
            return st
        st = ops.put_long_multi(self.ctx, st, items, handler=hd.H_WRITE,
                                tokens=[1, 2],
                                asynchronous=not self.transport.acked)
        if self.transport.acked:
            # Replies coalesce across >MTU segmentation (only the final
            # packet of a halo row is acked), so each halo *message*
            # earns exactly one credit regardless of how many packets
            # the transport split it into.
            # replies for token 1 come from puts I sent up, etc.
            st = ops.wait_replies(self.ctx, st, 1, has_up)
            st = ops.wait_replies(self.ctx, st, 2, has_down)
        return st

    def _drain_acks(self, st: PgasState) -> PgasState:
        """Loop exit for the piggyback plan: the last iteration's acks
        are still ledgered at the halo receivers; ship them home (the
        token-1 ledger lives at up-put receivers = the down link's
        senders, and vice versa) and consume the final credit."""
        if not self._use_piggyback:
            return st
        me = self.ctx.my_id()
        st = ops.drain_deferred_acks(self.ctx, st, self.down, token=1)
        st = ops.drain_deferred_acks(self.ctx, st, self.up, token=2)
        st = ops.wait_replies(self.ctx, st, 1,
                              (me > 0).astype(jnp.int32))
        st = ops.wait_replies(self.ctx, st, 2,
                              (me < self.kernels - 1).astype(jnp.int32))
        return st

    def _stencil(self, block_pad: jnp.ndarray, kid) -> jnp.ndarray:
        """block_pad: (rows+2, n) with halo rows attached.  (The Pallas
        variant of this loop is benchmarked separately in
        benchmarks/bench_utilization.py; on the CPU host the jnp form is
        what XLA vectorizes best, mirroring the paper's SW/HW split.)"""
        up = block_pad[:-2]
        down = block_pad[2:]
        mid = block_pad[1:-1]
        left = jnp.pad(mid[:, :-1], ((0, 0), (1, 0)))
        right = jnp.pad(mid[:, 1:], ((0, 0), (0, 1)))
        stencil = 0.25 * (up + down + left + right)
        rows, n = mid.shape
        grow = kid * rows + jax.lax.broadcasted_iota(jnp.int32, (rows, n), 0)
        gcol = jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1)
        interior = ((grow > 0) & (grow < self.n - 1)
                    & (gcol > 0) & (gcol < n - 1))
        return jnp.where(interior, stencil.astype(mid.dtype), mid)

    def _iteration(self, st: PgasState, block: jnp.ndarray, it=None):
        n = self.n
        kid = self.ctx.my_id()
        st = self._halo_exchange(st, block, it)
        top_halo = st.segment[:n]
        bot_halo = st.segment[n:2 * n]
        # boundary kernels have no halo: use zero rows (masked anyway)
        top = jnp.where(kid > 0, top_halo, 0.0)
        bot = jnp.where(kid < self.kernels - 1, bot_halo, 0.0)
        pad = jnp.concatenate([top[None], block, bot[None]], axis=0)
        block = self._stencil(pad, kid)
        st = ops.barrier(self.ctx, st)
        return st, block

    # -- host-level driver ------------------------------------------------------

    def build(self):
        """Returns a jitted function (grid_blocks) -> grid_blocks running
        all iterations; grid_blocks: (kernels, rows, n) sharded."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        ctx = self.ctx

        def per_kernel(st, block):
            st = jax.tree.map(lambda x: x[0], st)
            block = block[0]

            def body(carry, it):
                st, blk = carry
                st, blk = self._iteration(st, blk, it)
                return (st, blk), ()

            (st, block), _ = jax.lax.scan(body, (st, block),
                                          jnp.arange(self.iters))
            st = self._drain_acks(st)
            return (jax.tree.map(lambda x: x[None], st), block[None])

        spec = P(("kernel",))
        fn = shard_map(per_kernel, mesh=self.mesh,
                           in_specs=(spec, spec), out_specs=(spec, spec))
        return jax.jit(fn)

    def run(self, grid: np.ndarray):
        """Run on a host grid (n, n); returns the final grid."""
        from repro.core.address_space import GlobalAddressSpace

        gas = GlobalAddressSpace(self.ctx)
        st = gas.make_global_state()
        blocks = jnp.asarray(grid.reshape(self.kernels, self.rows, self.n))
        fn = self.build()
        st, out = fn(st, blocks)
        return np.asarray(out).reshape(self.n, self.n)


def jacobi_reference(grid: np.ndarray, iters: int) -> np.ndarray:
    """Single-kernel oracle."""
    from repro.kernels.jacobi.ref import jacobi_step_ref
    x = jnp.asarray(grid)
    step = jax.jit(jacobi_step_ref)
    for _ in range(iters):
        x = step(x)
    return np.asarray(x)
