"""HUMboldt: the two-sided baseline (paper Sec. II-C3).

HUMboldt is the MPI-like protocol previously built on Galapagos that the
paper contrasts with Shoal's one-sided AMs.  Its exchange is a 4-phase
rendezvous:

    1. sender  -> receiver : request
    2. receiver -> sender  : clear-to-send (ack)
    3. sender  -> receiver : data
    4. receiver -> sender  : completion

i.e. four link traversals (two round trips) where an async Shoal put
needs one and an acked put two.  We reproduce it so the microbenchmarks
can measure the one-sided advantage the PGAS model buys — the paper's
central performance argument (Secs. II-A3, II-C3).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import am
from repro.core import gascore as gc
from repro.core import ops
from repro.core.state import PgasState, ShoalContext


def sendrecv(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray,
             pattern: ops.Pattern, *, token: int = 0):
    """HUM_Send/HUM_Recv pair, collectivized: kernels on the source side
    of ``pattern`` send ``payload``; destination kernels receive it.

    Returns ``(state, received)``.  Costs 4 link traversals per packet
    (vs 1-2 for a Shoal put): measured head-to-head in
    ``benchmarks/bench_latency.py``.
    """
    nwords = int(payload.size)
    limit = ctx.transport.max_packet_words
    rev = [(d, s) for (s, d) in pattern]
    parts = []
    for off, w in ops._segments(nwords, limit):
        # 1. request (header-only, async: the protocol's own acks follow)
        hdr = am.encode(
            type=am.make_type(am.SHORT, asynchronous=True),
            src=ctx.my_id(), dst=ops._dst_of(ctx, pattern), nwords=w,
            token=token, seq=off)
        hdr = ops._mask_nonparticipants(ctx, pattern, hdr)
        req, _ = ops._exchange(ctx, pattern, hdr, None)
        # 2. clear-to-send back to the sender
        req_h = am.decode(req)
        cts = am.encode(
            type=am.make_type(am.SHORT, asynchronous=True),
            src=req_h.dst, dst=req_h.src, nwords=req_h.nwords, token=token)
        cts = jnp.where(req_h.msg_class == am.SHORT, cts, jnp.zeros_like(cts))
        cts_back, _ = ops._exchange(ctx, rev, cts, None)
        # 3. data (sender may proceed only once cleared: data dependence
        #    on the CTS header enforces the ordering the threads had)
        cleared = am.decode(cts_back).msg_class == am.SHORT
        chunk = payload.reshape(-1)[off:off + w]
        data_hdr = am.encode(
            type=am.make_type(am.MEDIUM, asynchronous=True, fifo=True),
            src=ctx.my_id(), dst=ops._dst_of(ctx, pattern), nwords=w,
            token=token, seq=off)
        data_hdr = jnp.where(cleared, data_hdr, jnp.zeros_like(data_hdr))
        data_hdr = ops._mask_nonparticipants(ctx, pattern, data_hdr)
        buf = chunk * cleared.astype(chunk.dtype)
        dh, dp = ops._exchange(ctx, pattern, data_hdr, buf)
        dhh = am.decode(dh)
        state, part = gc.ingress_medium(state, dhh, dp, w)
        # 4. completion back to the sender (bumps the sender's credits,
        #    so wait_replies works identically across both libraries)
        comp = am.encode(
            type=am.make_type(am.SHORT, asynchronous=True, reply=True),
            src=dhh.dst, dst=dhh.src, token=token)
        comp = jnp.where(dhh.msg_class == am.MEDIUM, comp, jnp.zeros_like(comp))
        comp_back, _ = ops._exchange(ctx, rev, comp, None)
        state = gc.ingress_reply(state, am.decode(comp_back))
        parts.append(part)
    received = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return state, received


HOPS_PER_MESSAGE = 4  # for the analytic latency model
