"""Handler functions and reply/credit counters (paper Secs. II-C1, III-A).

GASNet-style AMs carry a handler ID; the receiver runs the handler on
arrival.  The paper keeps user-defined handlers in software but restricts
hardware kernels to a built-in set, with reply bookkeeping absorbed into
the runtime.  We take the same position for *all* kernels: handlers are
pure functions ``(region, payload) -> region`` fixed at trace time and
dispatched with ``lax.switch`` — the dataflow analogue of the GAScore's
handler wrapper, and the only form that maps onto an SPMD accelerator.

``region`` is the destination-segment slice the payload lands on, so the
built-ins express the classic one-sided verbs: overwrite (plain put),
accumulate (put-with-reduce), min/max.  Reply counting does not go
through this table: replies are consumed by the GAScore ingress stage
itself (:mod:`repro.core.gascore`), as in the paper.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

# Built-in handler IDs (stable ABI; configs and tests use these).
H_NOP = 0
H_WRITE = 1
H_ADD = 2
H_MAX = 3
H_MIN = 4
NUM_BUILTIN = 5

# Credit-counter file size per kernel: tokens index into this.
NUM_TOKENS = 16

HandlerFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

_BUILTINS: tuple[tuple[str, HandlerFn], ...] = (
    ("nop", lambda region, payload: region),
    ("write", lambda region, payload: payload.astype(region.dtype)),
    ("add", lambda region, payload: region + payload.astype(region.dtype)),
    ("max", lambda region, payload: jnp.maximum(region, payload.astype(region.dtype))),
    ("min", lambda region, payload: jnp.minimum(region, payload.astype(region.dtype))),
)


class HandlerTable:
    """Trace-time-frozen handler registry.

    Users may register additional pure handlers before tracing (the
    software-kernel freedom the paper preserves); the table is then
    baked into the compiled program via ``lax.switch``.
    """

    def __init__(self):
        self._entries: list[tuple[str, HandlerFn]] = list(_BUILTINS)

    def register(self, name: str, fn: HandlerFn) -> int:
        """Register a custom handler; returns its handler ID."""
        self._entries.append((name, fn))
        return len(self._entries) - 1

    def __len__(self) -> int:
        return len(self._entries)

    def names(self) -> Sequence[str]:
        return [n for n, _ in self._entries]

    def dispatch(self, handler_id, region: jnp.ndarray, payload: jnp.ndarray):
        """Run handler ``handler_id`` on (region, payload) -> new region.

        ``handler_id`` may be traced; dispatch is a ``lax.switch`` over
        the frozen table, exactly one branch of which executes.
        """
        branches = [
            (lambda r, p, f=fn: f(r, p)) for _, fn in self._entries
        ]
        idx = jnp.clip(handler_id, 0, len(branches) - 1)
        return jax.lax.switch(idx, branches, region, payload)


DEFAULT_TABLE = HandlerTable()


def bump_credit(credits: jnp.ndarray, token, n=1) -> jnp.ndarray:
    """credits[token] += n  (reply bookkeeping; paper Sec. III-A)."""
    return credits.at[token].add(jnp.asarray(n, credits.dtype))


def drain_credits(credits: jnp.ndarray, token, n) -> jnp.ndarray:
    """Consume ``n`` credits after a wait (GASNet wait-reply semantics)."""
    return credits.at[token].add(jnp.asarray(-n, credits.dtype))
