"""The GAScore: per-kernel AM engine (paper Sec. III-C, Fig. 3).

The hardware GAScore is a DMA engine shared by all kernels on an FPGA:
``xpams_tx``/``am_tx`` build outgoing packets (reading memory-sourced
payloads through the AXI DataMover), ``am_rx``/``xpams_rx`` parse
incoming packets, write Long payloads to memory, hand Medium payloads to
kernels, run handlers, and emit the automatic reply.

Here each stage is a pure function over ``(header, payload, state)``.
The correspondence:

    am_tx / DataMover read   -> :func:`egress`   (dynamic_slice from segment)
    am_rx / DataMover write  -> :func:`ingress_long` (dynamic_update_slice)
    xpams_rx handler+reply   -> :func:`ingress_*` + :func:`auto_reply`
    hold_buffer              -> dataflow ordering (a reply is data-dependent
                                on the segment write, so it cannot overtake it)

One deliberate refinement over the paper: the paper's GAScore is a
monolith that must decode every message class on every packet, and its
*future work* section proposes a modular API where only the datapaths an
application uses are instantiated.  We implement that refinement: each
``ingress_*`` below compiles only its own datapath, and an op call site
only lowers the stages it needs.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import am
from repro.core import handlers as hd
from repro.core.state import PgasState, ShoalContext


def _lane_mask(nwords, width: int, dtype=jnp.bool_):
    """mask[i] = i < nwords   (valid payload lanes in a fixed-size buffer)."""
    return (lax.iota(jnp.int32, width) < nwords).astype(dtype)


def egress(ctx: ShoalContext, state: PgasState, hdr: am.Header,
           fifo_payload: jnp.ndarray | None, packet_words: int):
    """Build the outgoing payload buffer (am_tx + DataMover read path).

    FIFO-variant AMs (paper Sec. III-A) carry payload straight from the
    kernel; memory-variant AMs read ``nwords`` at ``src_addr`` from the
    local segment.  Returns a (packet_words,) buffer.
    """
    if fifo_payload is not None:
        pay = fifo_payload.astype(state.segment.dtype)
        if pay.shape != (packet_words,):
            pay = jnp.pad(pay.reshape(-1), (0, packet_words - pay.size))
    else:
        addr = jnp.clip(hdr.src_addr, 0, ctx.segment_words - packet_words)
        pay = lax.dynamic_slice(state.segment, (addr,), (packet_words,))
    mask = _lane_mask(hdr.nwords, packet_words, pay.dtype)
    return pay * mask


def ingress_long(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                 payload: jnp.ndarray, packet_words: int) -> PgasState:
    """Long-put ingress: payload -> shared memory via handler (am_rx path).

    The handler (write/add/max/min/custom) is applied to the destination
    region, so a Long put with H_ADD is a one-sided remote accumulate.
    Non-participating kernels see a NOP header and leave their segment
    bit-identical.
    """
    active = hdr.msg_class == am.LONG
    addr = jnp.clip(hdr.dst_addr, 0, ctx.segment_words - packet_words)
    region = lax.dynamic_slice(state.segment, (addr,), (packet_words,))
    new_region = ctx.handlers.dispatch(hdr.handler, region, payload)
    lanes = _lane_mask(hdr.nwords, packet_words)
    new_region = jnp.where(lanes & active, new_region, region)
    segment = lax.dynamic_update_slice(state.segment, new_region, (addr,))
    state = PgasState(
        segment=segment,
        credits=state.credits,
        barrier_epoch=state.barrier_epoch,
        rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0),
        tx_words=state.tx_words,
        error=state.error,
    )
    return state


def ingress_strided(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                    payload: jnp.ndarray, blk_words: int, nblocks: int) -> PgasState:
    """Strided Long-put ingress: scatter ``nblocks`` blocks of
    ``blk_words`` to ``dst_addr + i*stride`` (paper carries strided AMs
    forward from THeGASNet).  Block geometry is static (trace-time);
    the stride itself may be traced."""
    active = hdr.msg_class == am.LONG

    def body(i, seg):
        blk = lax.dynamic_slice(payload, (i * blk_words,), (blk_words,))
        addr = jnp.clip(hdr.dst_addr + i * hdr.stride, 0,
                        ctx.segment_words - blk_words)
        region = lax.dynamic_slice(seg, (addr,), (blk_words,))
        new = ctx.handlers.dispatch(hdr.handler, region, blk)
        new = jnp.where(active, new, region)
        return lax.dynamic_update_slice(seg, new, (addr,))

    segment = lax.fori_loop(0, nblocks, body, state.segment)
    return dataclasses_replace(state, segment=segment,
                               rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))


def ingress_medium(state: PgasState, hdr: am.Header, payload: jnp.ndarray,
                   packet_words: int):
    """Medium-put ingress: deliver payload to the kernel (xpams_rx "To
    Kernels" path).  Returns (state, delivered) where ``delivered`` is
    zero-masked on non-participating kernels."""
    active = hdr.msg_class == am.MEDIUM
    lanes = _lane_mask(hdr.nwords, packet_words, payload.dtype)
    delivered = payload * lanes * active.astype(payload.dtype)
    state = dataclasses_replace(
        state, rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))
    return state, delivered


def ingress_short(ctx: ShoalContext, state: PgasState, hdr: am.Header) -> PgasState:
    """Short ingress: signaling.  The handler runs on a one-word region of
    the credit file at ``token`` with ``dst_addr`` as its argument, so
    H_ADD implements counting semaphores (the paper's primary Short use).
    Reply messages (FLAG_REPLY) bump the credit counter directly: reply
    management is absorbed into the runtime (paper Sec. III-A)."""
    is_short = hdr.msg_class == am.SHORT
    is_reply = is_short & hdr.flag(am.FLAG_REPLY)
    is_user = is_short & ~hdr.flag(am.FLAG_REPLY)

    token = jnp.clip(hdr.token, 0, hd.NUM_TOKENS - 1)
    # replies: credits[token] += 1
    credits = state.credits.at[token].add(is_reply.astype(jnp.int32))
    # user shorts: handler over credits[token] with arg payload [dst_addr]
    region = lax.dynamic_slice(credits, (token,), (1,))
    arg = hdr.dst_addr.astype(credits.dtype).reshape(1)
    new_region = ctx.handlers.dispatch(hdr.handler, region, arg)
    new_region = jnp.where(is_user, new_region, region)
    credits = lax.dynamic_update_slice(credits, new_region, (token,))
    return dataclasses_replace(state, credits=credits)


def serve_get(ctx: ShoalContext, state: PgasState, hdr: am.Header,
              packet_words: int):
    """Get-request service: read ``nwords`` at ``src_addr`` from the local
    segment and return (data_header, data_payload) to ship back.  The
    response is marked as a reply so the requester's credit bumps on
    receipt — for gets, the data return *is* the reply."""
    is_get = hdr.flag(am.FLAG_GET)
    addr = jnp.clip(hdr.src_addr, 0, ctx.segment_words - packet_words)
    data = lax.dynamic_slice(state.segment, (addr,), (packet_words,))
    data = data * _lane_mask(hdr.nwords, packet_words, data.dtype)
    data = data * is_get.astype(data.dtype)
    # Response header is NOP unless this really was a get request, so
    # non-participating kernels ship nothing back.
    resp_type = jnp.where(
        is_get,
        hdr.msg_class | am.FLAG_REPLY | am.FLAG_ASYNC,
        jnp.zeros((), jnp.int32),
    ).astype(jnp.int32)
    resp_hdr = am.encode(
        type=0, src=hdr.dst, dst=hdr.src, nwords=hdr.nwords,
        dst_addr=hdr.dst_addr, token=hdr.token,
        handler=hdr.handler,
    ).at[0].set(resp_type)
    resp_hdr = jnp.where(is_get, resp_hdr, jnp.zeros_like(resp_hdr))
    state = dataclasses_replace(
        state, tx_words=state.tx_words + jnp.where(is_get, hdr.nwords, 0))
    return state, resp_hdr, data


def auto_reply(hdr: am.Header) -> jnp.ndarray:
    """Build the automatic reply header for an acked AM; NOP (all-zero)
    when the message was asynchronous, a NOP, or itself a reply."""
    rep = am.reply_for(hdr)
    suppress = (hdr.msg_class == am.NOP) | hdr.flag(am.FLAG_ASYNC) | hdr.flag(am.FLAG_REPLY)
    return jnp.where(suppress, jnp.zeros_like(rep), rep)


def ingress_reply(state: PgasState, hdr: am.Header) -> PgasState:
    """Reply ingress at the original sender: bump credits[token]."""
    is_reply = hdr.flag(am.FLAG_REPLY)
    token = jnp.clip(hdr.token, 0, hd.NUM_TOKENS - 1)
    credits = state.credits.at[token].add(is_reply.astype(jnp.int32))
    return dataclasses_replace(state, credits=credits)


def dataclasses_replace(state: PgasState, **kw) -> PgasState:
    """dataclasses.replace for the registered-dataclass pytree."""
    fields = dict(
        segment=state.segment, credits=state.credits,
        barrier_epoch=state.barrier_epoch, rx_words=state.rx_words,
        tx_words=state.tx_words, error=state.error,
    )
    fields.update(kw)
    return PgasState(**fields)
