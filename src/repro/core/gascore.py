"""The GAScore: per-kernel AM engine (paper Sec. III-C, Fig. 3).

The hardware GAScore is a DMA engine shared by all kernels on an FPGA:
``xpams_tx``/``am_tx`` build outgoing packets (reading memory-sourced
payloads through the AXI DataMover), ``am_rx``/``xpams_rx`` parse
incoming packets, write Long payloads to memory, hand Medium payloads to
kernels, run handlers, and emit the automatic reply.

Here each stage is a pure function over ``(header, payload, state)``.
The correspondence:

    am_tx / DataMover read   -> :func:`egress`   (dynamic_slice from segment)
    am_rx / DataMover write  -> :func:`ingress_long` (dynamic_update_slice)
    xpams_rx handler+reply   -> :func:`ingress_*` + :func:`auto_reply`
    hold_buffer              -> dataflow ordering (a reply is data-dependent
                                on the segment write, so it cannot overtake it)

One deliberate refinement over the paper: the paper's GAScore is a
monolith that must decode every message class on every packet, and its
*future work* section proposes a modular API where only the datapaths an
application uses are instantiated.  We implement that refinement: each
``ingress_*`` below compiles only its own datapath, and an op call site
only lowers the stages it needs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import am
from repro.core import handlers as hd
from repro.core.state import PgasState, ShoalContext
from repro.kernels.am_pack.ref import strided_indices

_I_NWORDS = am.FIELDS.index("nwords")
_I_SRC_ADDR = am.FIELDS.index("src_addr")


def _lane_mask(nwords, width: int, dtype=jnp.bool_):
    """mask[i] = i < nwords   (valid payload lanes in a fixed-size buffer)."""
    return (lax.iota(jnp.int32, width) < nwords).astype(dtype)


def _pad_segment(segment: jnp.ndarray, packet_words: int) -> jnp.ndarray:
    """Append a packet-width zero tail so a partial final segment of a
    batched >MTU plan (lanes masked beyond ``nwords``, buffer still
    ``packet_words`` wide) can read/land flush against the segment end
    without the address clip sliding the window."""
    return jnp.concatenate(
        [segment, jnp.zeros((packet_words,), segment.dtype)])


def egress(ctx: ShoalContext, state: PgasState, hdr: am.Header,
           fifo_payload: jnp.ndarray | None, packet_words: int):
    """Build the outgoing payload buffer (am_tx + DataMover read path).

    FIFO-variant AMs (paper Sec. III-A) carry payload straight from the
    kernel; memory-variant AMs read ``nwords`` at ``src_addr`` from the
    local segment.  Returns a (packet_words,) buffer.
    """
    if fifo_payload is not None:
        pay = fifo_payload.astype(state.segment.dtype)
        if pay.shape != (packet_words,):
            pay = jnp.pad(pay.reshape(-1), (0, packet_words - pay.size))
    else:
        addr = jnp.clip(hdr.src_addr, 0, ctx.segment_words - packet_words)
        pay = lax.dynamic_slice(state.segment, (addr,), (packet_words,))
    mask = _lane_mask(hdr.nwords, packet_words, pay.dtype)
    return pay * mask


def egress_batch(ctx: ShoalContext, state: PgasState, hdr_rows: jnp.ndarray,
                 fifo_payload: jnp.ndarray | None, packet_words: int):
    """Batched :func:`egress`: one ``(nseg, packet_words)`` buffer for a
    whole segmentation plan (am_tx reading every segment of one >MTU AM
    in a single pass).

    FIFO AMs slice the flat kernel payload row-wise (every row but the
    last is full, so a pad + reshape is exact); memory-sourced AMs
    gather each row at its own ``src_addr``.  Per-row lanes beyond that
    row's ``nwords`` are zeroed.
    """
    nseg = hdr_rows.shape[0]
    if fifo_payload is not None:
        flat = fifo_payload.astype(state.segment.dtype).reshape(-1)
        flat = jnp.pad(flat, (0, nseg * packet_words - flat.size))
        rows = flat.reshape(nseg, packet_words)
    else:
        seg_p = _pad_segment(state.segment, packet_words)
        addrs = jnp.clip(hdr_rows[:, _I_SRC_ADDR], 0, ctx.segment_words)
        rows = jax.vmap(
            lambda a: lax.dynamic_slice(seg_p, (a,), (packet_words,))
        )(addrs)
    lanes = lax.broadcasted_iota(jnp.int32, (nseg, packet_words), 1)
    mask = (lanes < hdr_rows[:, _I_NWORDS][:, None]).astype(rows.dtype)
    return rows * mask


def ingress_long(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                 payload: jnp.ndarray, packet_words: int) -> PgasState:
    """Long-put ingress: payload -> shared memory via handler (am_rx path).

    The handler (write/add/max/min/custom) is applied to the destination
    region, so a Long put with H_ADD is a one-sided remote accumulate.
    Non-participating kernels see a NOP header and leave their segment
    bit-identical.
    """
    st = _ingress_long_padded(
        ctx, dataclasses_replace(state,
                                 segment=_pad_segment(state.segment,
                                                      packet_words)),
        hdr, payload, packet_words)
    return dataclasses_replace(st, segment=st.segment[:ctx.segment_words])


def _ingress_long_padded(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                         payload: jnp.ndarray, packet_words: int,
                         gate=None) -> PgasState:
    """:func:`ingress_long` body over a state whose segment already has
    the packet-width pad (see :func:`_pad_segment`) — so a batched scan
    pads once outside the loop, not once per segment.  ``gate`` further
    restricts application (the reliable path passes its dedup verdict:
    already-seen rows must not re-apply)."""
    active = hdr.msg_class == am.LONG
    if gate is not None:
        active = active & gate
    addr = jnp.clip(hdr.dst_addr, 0, ctx.segment_words)
    region = lax.dynamic_slice(state.segment, (addr,), (packet_words,))
    new_region = ctx.handlers.dispatch(hdr.handler, region, payload)
    lanes = _lane_mask(hdr.nwords, packet_words)
    new_region = jnp.where(lanes & active, new_region, region)
    segment = lax.dynamic_update_slice(state.segment, new_region, (addr,))
    return dataclasses_replace(
        state, segment=segment,
        rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))


def ingress_long_batch(ctx: ShoalContext, state: PgasState,
                       hdr_rows: jnp.ndarray, pay_rows: jnp.ndarray,
                       packet_words: int) -> PgasState:
    """Absorb a whole ``(nseg, ...)`` segment stack: a ``lax.scan`` of
    :func:`ingress_long` over the rows (one fused segment update per
    row; no collectives inside the loop, and the packet-width pad is
    applied once around the scan, not per row)."""
    if hdr_rows.shape[0] == 1:
        return ingress_long(ctx, state, am.decode(hdr_rows[0]), pay_rows[0],
                            packet_words)

    def body(st, row):
        h, p = row
        return _ingress_long_padded(ctx, st, am.decode(h), p,
                                    packet_words), ()

    state = dataclasses_replace(
        state, segment=_pad_segment(state.segment, packet_words))
    state, _ = lax.scan(body, state, (hdr_rows, pay_rows))
    return dataclasses_replace(state,
                               segment=state.segment[:ctx.segment_words])


def ingress_medium_batch(state: PgasState, hdr_rows: jnp.ndarray,
                         pay_rows: jnp.ndarray, packet_words: int):
    """Batched :func:`ingress_medium`; returns ``(state, delivered)``
    with ``delivered`` the flattened ``(nseg * packet_words,)`` lane
    stream (full rows first, so the first ``nwords`` lanes are the
    message payload)."""
    if hdr_rows.shape[0] == 1:
        st, part = ingress_medium(state, am.decode(hdr_rows[0]), pay_rows[0],
                                  packet_words)
        return st, part

    def body(st, row):
        h, p = row
        st, part = ingress_medium(st, am.decode(h), p, packet_words)
        return st, part

    state, parts = lax.scan(body, state, (hdr_rows, pay_rows))
    return state, parts.reshape(-1)


def ingress_strided(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                    payload: jnp.ndarray, blk_words: int, nblocks: int,
                    ordered: bool = False) -> PgasState:
    """Strided Long-put ingress: scatter blocks of ``blk_words`` to
    ``dst_addr + i*stride`` (paper carries strided AMs forward from
    THeGASNet).

    Vectorized as one flat gather -> handler -> scatter over the whole
    packed payload (the same index map as the :mod:`repro.kernels.am_pack`
    DataMover kernels) instead of a per-block ``fori_loop``.  ``nblocks``
    / ``blk_words`` are the *static* packet capacity; the actual block
    count is ``hdr.nblocks`` (lanes beyond it are dropped), so one shape
    serves every row of a batched segmentation plan.

    Overlapping blocks (``stride < blk_words``) gather the destination
    region ONCE and scatter duplicate indices in undefined lane order, so
    last-writer-wins and read-modify-write handlers are both wrong for
    them; pass ``ordered=True`` (the op layer does so automatically when
    the static stride can overlap) to take the block-sequential
    :func:`ingress_strided_seq` path instead.
    """
    if ordered:
        return ingress_strided_seq(ctx, state, hdr, payload, blk_words,
                                   nblocks)
    active = hdr.msg_class == am.LONG
    flat = nblocks * blk_words
    idx = strided_indices(hdr.dst_addr, hdr.stride, blk_words, nblocks)
    blk_i = lax.iota(jnp.int32, flat) // blk_words
    valid = active & (blk_i < hdr.nblocks) \
        & _lane_mask(hdr.nwords, flat) & (idx >= 0) \
        & (idx < ctx.segment_words)
    idx_c = jnp.clip(idx, 0, ctx.segment_words - 1)
    region = state.segment[idx_c]
    new = ctx.handlers.dispatch(hdr.handler, region, payload)
    # invalid lanes scatter out of bounds and are dropped
    scatter_idx = jnp.where(valid, idx_c, ctx.segment_words)
    segment = state.segment.at[scatter_idx].set(
        jnp.where(valid, new, region), mode="drop")
    return dataclasses_replace(state, segment=segment,
                               rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))


def ingress_strided_seq(ctx: ShoalContext, state: PgasState, hdr: am.Header,
                        payload: jnp.ndarray, blk_words: int,
                        nblocks: int) -> PgasState:
    """Block-sequential :func:`ingress_strided`: a ``lax.scan`` over the
    blocks so each block's gather sees every earlier block's scatter.
    This restores the sequential last-writer-wins semantics (and correct
    read-modify-write accumulation for H_ADD/H_MAX/H_MIN) when blocks
    alias (``stride < blk_words``), at the cost of a length-``nblocks``
    dependency chain instead of one flat scatter."""
    active = hdr.msg_class == am.LONG

    def body(segment, i):
        lane = lax.iota(jnp.int32, blk_words)
        idx = hdr.dst_addr + i * hdr.stride + lane
        flat_lane = i * blk_words + lane
        valid = active & (i < hdr.nblocks) & (flat_lane < hdr.nwords) \
            & (idx >= 0) & (idx < ctx.segment_words)
        idx_c = jnp.clip(idx, 0, ctx.segment_words - 1)
        region = segment[idx_c]
        blk_pay = lax.dynamic_slice(payload, (i * blk_words,), (blk_words,))
        new = ctx.handlers.dispatch(hdr.handler, region, blk_pay)
        # invalid lanes scatter out of bounds and are dropped; indices
        # within one block never alias, so .set is well-defined here
        scatter_idx = jnp.where(valid, idx_c, ctx.segment_words)
        segment = segment.at[scatter_idx].set(
            jnp.where(valid, new, region), mode="drop")
        return segment, ()

    segment, _ = lax.scan(body, state.segment,
                          jnp.arange(nblocks, dtype=jnp.int32))
    return dataclasses_replace(
        state, segment=segment,
        rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))


def ingress_strided_batch(ctx: ShoalContext, state: PgasState,
                          hdr_rows: jnp.ndarray, pay_rows: jnp.ndarray,
                          blk_words: int, nblocks: int,
                          ordered: bool = False) -> PgasState:
    """Scan of :func:`ingress_strided` over a batched segment stack
    (``nblocks`` = static per-row block capacity).  ``ordered`` selects
    the block-sequential variant for aliasing strides."""
    if hdr_rows.shape[0] == 1:
        return ingress_strided(ctx, state, am.decode(hdr_rows[0]), pay_rows[0],
                               blk_words, nblocks, ordered)

    def body(st, row):
        h, p = row
        return ingress_strided(ctx, st, am.decode(h), p, blk_words, nblocks,
                               ordered), ()

    state, _ = lax.scan(body, state, (hdr_rows, pay_rows))
    return state


def ingress_medium(state: PgasState, hdr: am.Header, payload: jnp.ndarray,
                   packet_words: int):
    """Medium-put ingress: deliver payload to the kernel (xpams_rx "To
    Kernels" path).  Returns (state, delivered) where ``delivered`` is
    zero-masked on non-participating kernels."""
    active = hdr.msg_class == am.MEDIUM
    lanes = _lane_mask(hdr.nwords, packet_words, payload.dtype)
    delivered = payload * lanes * active.astype(payload.dtype)
    state = dataclasses_replace(
        state, rx_words=state.rx_words + jnp.where(active, hdr.nwords, 0))
    return state, delivered


def ingress_short(ctx: ShoalContext, state: PgasState, hdr: am.Header) -> PgasState:
    """Short ingress: signaling.  The handler runs on a one-word region of
    the credit file at ``token`` with ``dst_addr`` as its argument, so
    H_ADD implements counting semaphores (the paper's primary Short use).
    Reply messages (FLAG_REPLY) bump the credit counter directly: reply
    management is absorbed into the runtime (paper Sec. III-A)."""
    is_short = hdr.msg_class == am.SHORT
    is_reply = is_short & hdr.flag(am.FLAG_REPLY)
    is_user = is_short & ~hdr.flag(am.FLAG_REPLY)

    token = jnp.clip(hdr.token, 0, hd.NUM_TOKENS - 1)
    # replies: credits[token] += 1
    credits = state.credits.at[token].add(is_reply.astype(jnp.int32))
    # user shorts: handler over credits[token] with arg payload [dst_addr]
    region = lax.dynamic_slice(credits, (token,), (1,))
    arg = hdr.dst_addr.astype(credits.dtype).reshape(1)
    new_region = ctx.handlers.dispatch(hdr.handler, region, arg)
    new_region = jnp.where(is_user, new_region, region)
    credits = lax.dynamic_update_slice(credits, new_region, (token,))
    return dataclasses_replace(state, credits=credits)


def ingress_stack(ctx: ShoalContext, state: PgasState, hdr_rows: jnp.ndarray,
                  pay_rows: jnp.ndarray, packet_words: int) -> PgasState:
    """Mixed-class scanned ingress for a coalesced packet stack (the
    actor-mailbox flush path, :mod:`repro.actors`).

    Unlike :func:`ingress_long_batch`, whose rows are segments of ONE
    message, each row here is an independent tiny AM with its own class,
    handler, and token: Long rows land in the segment through their
    handler, Short rows run on the credit file (signals / coalesced
    credit returns / replies), NOP rows do nothing.  Both datapaths are
    class-gated per row, so one ``lax.scan`` absorbs a stack that mixes
    them freely — the dataflow analogue of the GAScore draining a burst
    of aggregated messages off one AXIS stream.
    """
    def body(st, row):
        h, p = row
        hd_ = am.decode(h)
        st = _ingress_long_padded(ctx, st, hd_, p, packet_words)
        st = ingress_short(ctx, st, hd_)
        st = ingress_ack_lanes(st, hd_)
        return st, ()

    state = dataclasses_replace(
        state, segment=_pad_segment(state.segment, packet_words))
    state, _ = lax.scan(body, state, (hdr_rows, pay_rows))
    return dataclasses_replace(state,
                               segment=state.segment[:ctx.segment_words])


def _serve_get_row(ctx: ShoalContext, seg_p: jnp.ndarray, hdr: am.Header,
                   packet_words: int):
    """Stateless get service for one packet over a segment that already
    has the packet-width pad (see :func:`_pad_segment`): returns
    ``(resp_hdr, data, tx_words)``."""
    is_get = hdr.flag(am.FLAG_GET)
    addr = jnp.clip(hdr.src_addr, 0, ctx.segment_words)
    data = lax.dynamic_slice(seg_p, (addr,), (packet_words,))
    data = data * _lane_mask(hdr.nwords, packet_words, data.dtype)
    data = data * is_get.astype(data.dtype)
    # Response header is NOP unless this really was a get request, so
    # non-participating kernels ship nothing back.
    resp_type = jnp.where(
        is_get,
        hdr.msg_class | am.FLAG_REPLY | am.FLAG_ASYNC,
        jnp.zeros((), jnp.int32),
    ).astype(jnp.int32)
    resp_hdr = am.encode(
        type=0, src=hdr.dst, dst=hdr.src, nwords=hdr.nwords,
        dst_addr=hdr.dst_addr, token=hdr.token,
        handler=hdr.handler, seq=hdr.seq,
    ).at[0].set(resp_type)
    resp_hdr = jnp.where(is_get, resp_hdr, jnp.zeros_like(resp_hdr))
    return resp_hdr, data, jnp.where(is_get, hdr.nwords, 0)


def serve_get(ctx: ShoalContext, state: PgasState, hdr: am.Header,
              packet_words: int):
    """Get-request service: read ``nwords`` at ``src_addr`` from the local
    segment and return (data_header, data_payload) to ship back.  The
    response is marked as a reply so the requester's credit bumps on
    receipt — for gets, the data return *is* the reply."""
    resp_hdr, data, tx = _serve_get_row(
        ctx, _pad_segment(state.segment, packet_words), hdr, packet_words)
    state = dataclasses_replace(state, tx_words=state.tx_words + tx)
    return state, resp_hdr, data


def serve_get_batch(ctx: ShoalContext, state: PgasState,
                    hdr_rows: jnp.ndarray, packet_words: int):
    """Vectorized get service over a ``(nseg, HDR_WORDS)`` request stack:
    every segment of a >MTU get is read in one pass and the whole
    response ships back as one fused packet stack."""
    seg_p = _pad_segment(state.segment, packet_words)
    resp_rows, data_rows, tx = jax.vmap(
        lambda h: _serve_get_row(ctx, seg_p, am.decode(h), packet_words)
    )(hdr_rows)
    state = dataclasses_replace(state, tx_words=state.tx_words + tx.sum())
    return state, resp_rows, data_rows


def auto_reply(hdr: am.Header) -> jnp.ndarray:
    """Build the automatic reply header for an acked AM; NOP (all-zero)
    when the message was asynchronous, a NOP, itself a reply, or
    defer-acked (the owed ack rides a later packet's piggyback lane)."""
    rep = am.reply_for(hdr)
    suppress = (hdr.msg_class == am.NOP) | hdr.flag(am.FLAG_ASYNC) \
        | hdr.flag(am.FLAG_REPLY) | hdr.flag(am.FLAG_DEFER_ACK)
    return jnp.where(suppress, jnp.zeros_like(rep), rep)


def ingress_ack_lanes(state: PgasState, hdr: am.Header) -> PgasState:
    """Process the deferred-ack / piggyback lanes of one ingressed packet.

    Two independent gates (a packet can carry both):

    * FLAG_DEFER_ACK on an acked (non-async) message: instead of a reply
      collective, ledger the owed ack — ``deferred_acks[token] += 1``.
      The ledger is keyed by the put's token, which the steady-state
      protocol uses as a link id: each link direction gets its own token
      so the acks ride home over the right reverse link.
    * FLAG_PIGGYBACK: this packet carries ``pb_count`` acks owed on
      ``pb_token`` from the sender's ledger — grant them:
      ``credits[pb_token] += pb_count``.
    """
    live = hdr.msg_class != am.NOP
    defer = live & hdr.flag(am.FLAG_DEFER_ACK) \
        & ~hdr.flag(am.FLAG_ASYNC) & ~hdr.flag(am.FLAG_REPLY)
    tok = jnp.clip(hdr.token, 0, hd.NUM_TOKENS - 1)
    deferred = state.deferred_acks.at[tok].add(defer.astype(jnp.int32))

    carry = live & hdr.flag(am.FLAG_PIGGYBACK)
    pb_tok = jnp.clip(hdr.pb_token, 0, hd.NUM_TOKENS - 1)
    credits = state.credits.at[pb_tok].add(
        jnp.where(carry, hdr.pb_count, 0).astype(jnp.int32))
    return dataclasses_replace(state, deferred_acks=deferred,
                               credits=credits)


def ingress_reply(state: PgasState, hdr: am.Header) -> PgasState:
    """Reply ingress at the original sender: bump credits[token]."""
    is_reply = hdr.flag(am.FLAG_REPLY)
    token = jnp.clip(hdr.token, 0, hd.NUM_TOKENS - 1)
    credits = state.credits.at[token].add(is_reply.astype(jnp.int32))
    return dataclasses_replace(state, credits=credits)


def ingress_reliable_stack(ctx: ShoalContext, state: PgasState,
                           hdr_rows: jnp.ndarray, pay_rows: jnp.ndarray,
                           packet_words: int, *, dedup: bool = True):
    """Dedup-gated Long-stack ingress for the lossy-transport path.

    Rows arrive out of a faulted exchange (drops already NOPed,
    CRC-failed rows already NOPed, duplicates materialised as extra
    rows — see :func:`repro.core.faults.deliver`), possibly REDELIVERED
    by a sender retransmitting after a lost ack.  The redelivery ledger
    makes application idempotent, keyed on (token, epoch, seq):

    * a row whose epoch is <= the last *completed* epoch on its token is
      stale — not applied, but a stale FINAL row still re-acks (the
      data landed earlier; it is the ack that keeps dying);
    * an in-flight row applies only if its segment bit is not yet in
      ``dedup_seen[token]``, then sets the bit;
    * when the final (non-async) row finds the arrival mask complete
      (bits 0..seg_final all set), the message completes:
      ``dedup_epoch[token]`` latches the epoch and the mask DRAINS TO
      ZERO — a quiescent receiver holds no ledger residue.

    One message per token may be in flight at a time (epochs on a token
    are totally ordered by the sender's ``send_epoch`` counter); the
    reliable put in :mod:`repro.core.ops` serialises this.  Segment
    stacks are limited to 31 rows so the arrival mask fits an int32.

    ``dedup=False`` keeps the CRC/drop handling but applies every
    delivered row unconditionally and acks every final row — the unsafe
    mode shoal-lint rule R5 exists to flag (a retransmitted H_ADD
    double-accumulates, a duplicated final row double-acks).

    Returns ``(state, ack_hdr)`` where ``ack_hdr`` is the reply header
    owed this round (NOP when no final row completed or re-acked).
    """
    def body(carry, row):
        st, ack = carry
        h_raw, p = row
        h = am.decode(h_raw)
        active = h.msg_class == am.LONG
        tok = jnp.clip(h.token, 0, hd.NUM_TOKENS - 1)
        seg_i = jnp.clip(h.seq // packet_words, 0, 30)
        bit = jnp.left_shift(jnp.int32(1), seg_i)
        is_final = active & ~h.flag(am.FLAG_ASYNC) & ~h.flag(am.FLAG_REPLY)

        if dedup:
            done = st.dedup_epoch[tok]
            stale = active & (h.epoch <= done)
            tracked = st.dedup_inflight[tok] == h.epoch
            seen = jnp.where(tracked, st.dedup_seen[tok], 0)
            fresh = active & ~stale & ((seen & bit) == 0)
            seen2 = jnp.where(active & ~stale, seen | bit, seen)
            # complete <=> final row present and bits 0..seg_i all set
            # (segments are contiguous, the final row has the top seq)
            complete = is_final & ~stale \
                & (seen2 == jnp.left_shift(bit, 1) - 1)
            track = active & ~stale
            st = dataclasses_replace(
                st,
                dedup_epoch=st.dedup_epoch.at[tok].set(
                    jnp.where(complete, h.epoch, done)),
                dedup_inflight=st.dedup_inflight.at[tok].set(
                    jnp.where(track, h.epoch, st.dedup_inflight[tok])),
                dedup_seen=st.dedup_seen.at[tok].set(
                    jnp.where(complete, 0,
                              jnp.where(track, seen2, st.dedup_seen[tok]))))
            ack_now = complete | (stale & is_final)
        else:
            fresh = active
            ack_now = is_final

        st = _ingress_long_padded(ctx, st, h, p, packet_words, gate=fresh)
        ack = jnp.where(ack_now, am.reply_for(h), ack)
        return (st, ack), ()

    state = dataclasses_replace(
        state, segment=_pad_segment(state.segment, packet_words))
    (state, ack_hdr), _ = lax.scan(
        body, (state, jnp.zeros((am.HDR_WORDS,), jnp.int32)),
        (hdr_rows, pay_rows))
    return dataclasses_replace(
        state, segment=state.segment[:ctx.segment_words]), ack_hdr


def dataclasses_replace(state: PgasState, **kw) -> PgasState:
    """dataclasses.replace for the registered-dataclass pytree."""
    import dataclasses as _dc

    return _dc.replace(state, **kw)
