"""Deterministic, seedable packet-fault injection at the ppermute boundary.

The paper's middleware runs over transports that are allowed to lose
things (TCP / UDP / raw Ethernet, Sec. II-B2); the collectivized wire in
this repo emulates the link with ``lax.ppermute``, which never loses
anything.  This module injects the losses back — *inside traced code*,
so the fault process composes with the scanned ingress, jit, and scan
exactly like real loss would, and two traces of the same program from
the same state see the *same* faults (the draws are a pure function of
``(seed, receiver id, token, epoch, round, direction)``, never of host
RNG state or trace order).

Faults are applied on the receiver side, to the ``(nseg, W)`` packet
stack that just came out of the collective:

* **drop** — the row is zeroed.  An all-zero row is the wire's explicit
  NOP, so a dropped packet is simply never seen, like a lost datagram.
* **corrupt** — one uniformly chosen bit of the row (header or payload)
  is flipped.  The CRC seal (:func:`repro.core.am.packet_crc_ok`)
  catches every single-bit flip; the receiver NOPs the row and latches
  ``ERR_CRC``, so corruption degenerates to drop + a sticky error bit.
* **duplicate** — the row is delivered twice.  :func:`deliver` returns a
  ``(2 * nseg, W)`` stack whose second half holds the duplicated rows
  (NOP elsewhere); the dedup ledger makes redelivery idempotent.

Only rows that are live on the wire (non-NOP type word) can fault — a
NOP row is the *absence* of a packet, there is nothing to lose.  Fault
probabilities are per-receiver traced scalars so one collective can mix
lossless (LOCAL/ICI) and lossy (DCN) links: receivers on a lossless
link pass probability 0 and the draws compare false everywhere.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import am

# direction salts: data stack vs the (reverse-link) ack
DIR_DATA = 0
DIR_REPLY = 1


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-link-class fault process: independent per-packet Bernoulli
    draws for drop / duplicate / corrupt, derived from ``seed``."""

    drop: float = 0.0
    dup: float = 0.0
    corrupt: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for name in ("drop", "dup", "corrupt"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"FaultModel.{name} must be in [0, 1], "
                                 f"got {p}")

    @property
    def lossless(self) -> bool:
        return self.drop == 0.0 and self.dup == 0.0 and self.corrupt == 0.0


def fault_key(model: FaultModel, receiver, token, epoch, rnd, direction):
    """The deterministic draw key.  Every argument may be traced; the
    chain of ``fold_in`` decorrelates receivers, messages (token +
    send epoch), retransmit rounds, and the data/reply directions while
    keeping the whole process reproducible across traces."""
    key = jax.random.PRNGKey(model.seed)
    for salt in (receiver, token, epoch, rnd, direction):
        key = jax.random.fold_in(key, jnp.asarray(salt, jnp.int32))
    return key


def inject(rows: jnp.ndarray, key, drop, dup, corrupt):
    """Apply one round of faults to a received ``(nseg, W)`` int32 stack.

    ``drop``/``dup``/``corrupt`` are per-receiver scalar probabilities
    (traced OK — pass 0.0 on lossless links).  Returns
    ``(rows_after, dup_mask)``: corrupt flips one uniform bit of the
    row, drop zeroes it (corrupt-then-drop: a packet both corrupted and
    lost is just lost), ``dup_mask`` marks surviving rows delivered
    twice.  Only live (non-NOP) rows fault.
    """
    nseg, width = rows.shape
    live = rows[:, am.FIELDS.index("type")] != 0
    kd, ku, kc, kb = jax.random.split(key, 4)
    dropm = live & (jax.random.uniform(kd, (nseg,)) < drop)
    dupm = live & (jax.random.uniform(ku, (nseg,)) < dup)
    corm = live & (jax.random.uniform(kc, (nseg,)) < corrupt)

    # corrupt: flip bit (b % 32) of lane (b // 32), b uniform on the row
    bit = jax.random.randint(kb, (nseg,), 0, width * 32)
    lane = jnp.arange(width, dtype=jnp.int32)[None, :]
    flip = jnp.where(lane == (bit // 32)[:, None],
                     jnp.uint32(1) << (bit % 32).astype(jnp.uint32)[:, None],
                     jnp.uint32(0))
    u = lax.bitcast_convert_type(rows, jnp.uint32)
    u = jnp.where(corm[:, None], u ^ flip, u)
    rows = lax.bitcast_convert_type(u, jnp.int32)

    rows = jnp.where(dropm[:, None], 0, rows)
    return rows, dupm & ~dropm


def deliver(rows: jnp.ndarray, key, drop, dup, corrupt):
    """Full receiver-side delivery: fault the stack and materialise
    duplicates.  Returns a ``(2 * nseg, W)`` stack — faulted rows first,
    then the duplicated rows (NOP where no duplicate fired) — ready for
    a dedup-gated scanned ingress."""
    faulted, dupm = inject(rows, key, drop, dup, corrupt)
    dups = jnp.where(dupm[:, None], faulted, 0)
    return jnp.concatenate([faulted, dups], axis=0)
