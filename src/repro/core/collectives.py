"""Collectives built from one-sided Shoal puts.

The paper positions AMs as the substrate on which higher communication
patterns are built (GASNet heritage: UPC/Chapel collectives sit on AM
puts/gets).  These ring algorithms are the specialization of
``put_long(handler=H_ADD)`` FIFO-variant AMs to a neighbor ring: each
step is one one-sided link traversal carrying a payload that is combined
at the receiver — exactly the GAScore's Long-with-accumulate datapath,
with the header machinery constant-folded away (every step's route,
size, and handler are trace-time constants, so the header words would be
dead code; the Table-I analogue in the benchmarks accounts for them
explicitly instead).

These are the ``comm_backend="shoal"`` primitives of the trainer.  The
``xla`` backend uses ``lax.psum``/``psum_scatter``/``all_gather`` and
lets the compiler fuse and overlap — that pair (modular AM engine vs
fused schedule) reproduces, at pod scale, the paper's own observation
that the GAScore's modularity costs latency vs a tightly integrated
datapath (Sec. IV-B1).

All functions run inside ``shard_map`` over ``axes``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _ring_perm(n: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


def _pad_to_chunks(x: jnp.ndarray, n: int):
    flat = x.reshape(-1)
    chunk = -(-flat.size // n)
    pad = chunk * n - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n, chunk), pad


def ring_reduce_scatter(x: jnp.ndarray, axes, n: int) -> jnp.ndarray:
    """Ring reduce-scatter of a replicated-shape per-kernel value.

    ``x`` is this kernel's full-size addend; returns this kernel's
    reduced chunk (flattened, chunk = ceil(size/n)).  n-1 steps, each a
    one-sided neighbor put with the H_ADD handler.
    """
    if n == 1:
        return x.reshape(-1)
    buf, _ = _pad_to_chunks(x, n)
    me = lax.axis_index(axes)
    perm = _ring_perm(n)

    def step(t, buf):
        # send the chunk we have been accumulating, receive our neighbor's.
        # The -1 phase shift makes rank i end up owning chunk i.
        send_idx = jnp.mod(me - t - 1, n)
        send = lax.dynamic_slice(buf, (send_idx, 0), (1, buf.shape[1]))
        recv = lax.ppermute(send, axes, perm)
        recv_idx = jnp.mod(me - t - 2, n)
        cur = lax.dynamic_slice(buf, (recv_idx, 0), (1, buf.shape[1]))
        return lax.dynamic_update_slice(buf, cur + recv, (recv_idx, 0))

    buf = lax.fori_loop(0, n - 1, step, buf)
    return lax.dynamic_slice(buf, (me, 0), (1, buf.shape[1]))[0]


def ring_all_gather(chunk: jnp.ndarray, axes, n: int) -> jnp.ndarray:
    """Ring all-gather: every kernel contributes ``chunk``; returns the
    (n, chunk) stack in kernel order.  n-1 one-sided neighbor puts."""
    chunk = chunk.reshape(-1)
    if n == 1:
        return chunk[None]
    me = lax.axis_index(axes)
    buf = jnp.zeros((n, chunk.size), chunk.dtype)
    buf = lax.dynamic_update_slice(buf, chunk[None], (me, 0))
    perm = _ring_perm(n)

    def step(t, buf):
        send_idx = jnp.mod(me - t, n)
        send = lax.dynamic_slice(buf, (send_idx, 0), (1, buf.shape[1]))
        recv = lax.ppermute(send, axes, perm)
        recv_idx = jnp.mod(me - t - 1, n)
        return lax.dynamic_update_slice(buf, recv, (recv_idx, 0))

    return lax.fori_loop(0, n - 1, step, buf)


def ring_all_reduce(x: jnp.ndarray, axes, n: int) -> jnp.ndarray:
    """Ring all-reduce = reduce-scatter + all-gather (2(n-1) puts, each
    of size/n words: bandwidth-optimal, the schedule every production
    collective library uses on a torus)."""
    if n == 1:
        return x
    shape, size = x.shape, x.size
    chunk = ring_reduce_scatter(x, axes, n)
    full = ring_all_gather(chunk, axes, n).reshape(-1)
    return full[:size].reshape(shape)


def all_to_all_vectored(x: jnp.ndarray, axes, n: int, *, tiled=True) -> jnp.ndarray:
    """Vectored-AM all-to-all: kernel i's block j lands at kernel j slot i.

    This is the Shoal Vectored Long put pattern over all kernel pairs —
    lowered directly to the ICI all-to-all (the hardware does the
    scatter, as the GAScore's DataMover does in the paper).  ``x`` has
    leading dim n (one block per destination).
    """
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=tiled)


def tree_barrier(axes) -> jnp.ndarray:
    """psum of a unit scalar: the dataflow barrier (see ops.barrier)."""
    return lax.psum(jnp.ones((), jnp.int32), axes)


def broadcast_from(x: jnp.ndarray, axes, n: int, root: int = 0) -> jnp.ndarray:
    """One-to-all: ring pipeline of n-1 one-sided puts from ``root``."""
    if n == 1:
        return x
    me = lax.axis_index(axes)
    buf = jnp.where(me == root, x, jnp.zeros_like(x))
    perm = _ring_perm(n)
    # payloads may legitimately contain zeros; a validity flag travels too
    flag = jnp.where(me == root, jnp.ones((), x.dtype), jnp.zeros((), x.dtype))

    def step2(_, carry):
        buf, flag = carry
        rb = lax.ppermute(buf, axes, perm)
        rf = lax.ppermute(flag, axes, perm)
        take = (rf > 0) & (flag == 0)
        buf = jnp.where(take, rb, buf)
        flag = jnp.maximum(flag, rf)
        return buf, flag

    buf, _ = lax.fori_loop(0, n - 1, step2, (buf, flag))
    return buf
