"""Active Message wire format (paper Sec. III-A).

Every Shoal message is ``header ++ payload``.  The header is a fixed
12-word int32 vector so it can travel through the same typed stream as
the payload (the GAScore parses it with dynamic slices, exactly like the
hardware IP parses the AXIS stream).  An all-zero header is an explicit
NOP: kernels that do not participate in a collectivized AM call receive
zeros from ``ppermute`` and must take no action and send no reply.

Word layout::

    0  type      class (NOP/SHORT/MEDIUM/LONG) | flag bits
    1  src       source kernel ID
    2  dst       destination kernel ID
    3  nwords    payload length in words
    4  dst_addr  destination segment word offset (Long), handler arg0 (Short)
    5  src_addr  source segment word offset (get / memory-sourced put)
    6  handler   handler-table index
    7  token     reply/credit counter index
    8  stride    words between strided blocks
    9  blk_words words per strided block
    10 nblocks   number of strided blocks
    11 seq       segment sequence number (k of n) for >MTU segmentation

The class/flag split mirrors the paper: three AM classes, each with
put/get direction, FIFO vs memory payload source, optional strided /
vectored addressing, and an async flag that suppresses the auto-reply.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

HDR_WORDS = 12

# -- message classes (word 0, low 3 bits) ------------------------------------
NOP = 0
SHORT = 1
MEDIUM = 2
LONG = 3
_CLASS_MASK = 0x7

# -- flags (word 0, high bits) ------------------------------------------------
FLAG_ASYNC = 1 << 3      # no auto-reply (UDP-like; paper Sec. III-A)
FLAG_GET = 1 << 4        # get request (data flows dst -> src)
FLAG_FIFO = 1 << 5       # payload from kernel, not from shared memory
FLAG_STRIDED = 1 << 6    # strided Long
FLAG_VECTORED = 1 << 7   # vectored Long
FLAG_REPLY = 1 << 8      # this message is an auto-generated reply

FIELDS = (
    "type", "src", "dst", "nwords", "dst_addr", "src_addr",
    "handler", "token", "stride", "blk_words", "nblocks", "seq",
)


@dataclasses.dataclass(frozen=True)
class Header:
    """Decoded header; every field is a (traced or concrete) int32 scalar."""

    type: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray
    nwords: jnp.ndarray
    dst_addr: jnp.ndarray
    src_addr: jnp.ndarray
    handler: jnp.ndarray
    token: jnp.ndarray
    stride: jnp.ndarray
    blk_words: jnp.ndarray
    nblocks: jnp.ndarray
    seq: jnp.ndarray

    @property
    def msg_class(self):
        return self.type & _CLASS_MASK

    def flag(self, bit: int):
        return (self.type & bit) != 0


def make_type(msg_class: int, *, asynchronous=False, get=False, fifo=False,
              strided=False, vectored=False, reply=False) -> int:
    t = msg_class & _CLASS_MASK
    if asynchronous:
        t |= FLAG_ASYNC
    if get:
        t |= FLAG_GET
    if fifo:
        t |= FLAG_FIFO
    if strided:
        t |= FLAG_STRIDED
    if vectored:
        t |= FLAG_VECTORED
    if reply:
        t |= FLAG_REPLY
    return t


def encode(**fields) -> jnp.ndarray:
    """Build a 12-word int32 header. Unspecified fields are zero."""
    unknown = set(fields) - set(FIELDS)
    if unknown:
        raise ValueError(f"unknown header fields: {unknown}")
    vals = [jnp.asarray(fields.get(f, 0), jnp.int32) for f in FIELDS]
    return jnp.stack(vals)


def decode(hdr: jnp.ndarray) -> Header:
    if hdr.shape != (HDR_WORDS,):
        raise ValueError(f"header must be ({HDR_WORDS},), got {hdr.shape}")
    return Header(*(hdr[i] for i in range(HDR_WORDS)))


def reply_for(hdr: Header) -> jnp.ndarray:
    """The automatic reply: a Short AM back to the source that bumps the
    source's credit counter for ``token`` (paper Sec. III-A: "Reply
    messages are Short messages that trigger a handler function that
    increments a variable")."""
    return encode(
        type=make_type(SHORT, asynchronous=True, reply=True),
        src=hdr.dst, dst=hdr.src, token=hdr.token,
    )


def is_nop(hdr: Header):
    return hdr.msg_class == NOP
