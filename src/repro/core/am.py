"""Active Message wire format (paper Sec. III-A): fused single packets.

On the wire a Shoal message is ``header ++ payload`` in ONE typed
stream — the hardware GAScore parses a single AXIS burst, it never
receives the header and the payload as separate transactions.  This
module reproduces that layout exactly: a *packet* is one int32 vector

    [ header (16 words) | extra (optional int32 section) | payload bits ]

where the payload's 32-bit lanes are bitcast to int32 (lossless both
ways), so a whole AM — header, vectored address list, data — crosses a
link in a **single** ``ppermute`` instead of one collective per section.
For >MTU AMs the op layer stacks ``nseg`` such packets into a
``(nseg, HDR_WORDS + packet_words)`` matrix and still ships them with
one collective (see :mod:`repro.core.ops`).

The header is a fixed 16-word int32 vector so it can travel through the
same typed stream as the payload (the GAScore parses it with dynamic
slices, exactly like the hardware IP parses the AXIS stream).  An
all-zero header is an explicit NOP: kernels that do not participate in a
collectivized AM call receive zeros from ``ppermute`` and must take no
action and send no reply.

Word layout::

    0  type      class (NOP/SHORT/MEDIUM/LONG) | flag bits
    1  src       source kernel ID
    2  dst       destination kernel ID
    3  nwords    payload length in words
    4  dst_addr  destination segment word offset (Long), handler arg0 (Short)
    5  src_addr  source segment word offset (get / memory-sourced put)
    6  handler   handler-table index
    7  token     reply/credit counter index
    8  stride    words between strided blocks
    9  blk_words words per strided block
    10 nblocks   number of strided blocks
    11 seq       segment sequence number (word offset) for >MTU segmentation
    12 pb_token  piggyback lane: token whose deferred acks ride this packet
    13 pb_count  piggyback lane: number of deferred acks carried
    14 epoch     send epoch: per-(src, token) message counter for dedup
    15 crc       integrity word over the whole packet (see seal_packet)

Integrity and delivery (the lossy-transport story): packets crossing a
link class that may drop/duplicate/corrupt (see
:class:`repro.runtime.transport.LossyTransport`) are *sealed* — the
``crc`` word is a rotate-XOR fold over every other lane of the packet,
guaranteed to flip when any single bit on the wire flips.  Receivers
check the seal (:func:`packet_crc_ok`) and treat failed rows as drops
(latching ``ERR_CRC``).  The ``epoch`` word stamps each message with a
per-(src, token) sequence number so redelivered packets (sender
retransmits after a lost ack) are recognised and not re-applied: the
receiver's dedup ledger keys on (token, epoch, seq).  A NOP row is
all-zero and its seal is zero, so NOPs pass the check for free.

The class/flag split mirrors the paper: three AM classes, each with
put/get direction, FIFO vs memory payload source, optional strided /
vectored addressing, and an async flag that suppresses the auto-reply.
Reply coalescing for segmented AMs rides on the async flag: the op
layer marks every segment but the last asynchronous, so an acked >MTU
message costs one reply total — one credit per *message*, not per
packet.

Reply piggybacking (the one-collective steady state): a message flagged
``FLAG_DEFER_ACK`` asks the receiver to *ledger* the owed ack
(``state.deferred_acks[token] += 1``) instead of shipping a header-only
reply collective.  A later message travelling the reverse link carries
the owed acks home in the piggyback lane: ``FLAG_PIGGYBACK`` plus
``pb_token``/``pb_count`` grant ``credits[pb_token] += pb_count`` at
ingress.  In a steady-state loop (Jacobi halo exchange) the next
iteration's data packet already crosses the reverse link, so the ack
collective disappears entirely.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import lax

HDR_WORDS = 16

# -- message classes (word 0, low 3 bits) ------------------------------------
NOP = 0
SHORT = 1
MEDIUM = 2
LONG = 3
_CLASS_MASK = 0x7

# -- flags (word 0, high bits) ------------------------------------------------
FLAG_ASYNC = 1 << 3      # no auto-reply (UDP-like; paper Sec. III-A)
FLAG_GET = 1 << 4        # get request (data flows dst -> src)
FLAG_FIFO = 1 << 5       # payload from kernel, not from shared memory
FLAG_STRIDED = 1 << 6    # strided Long
FLAG_VECTORED = 1 << 7   # vectored Long
FLAG_REPLY = 1 << 8      # this message is an auto-generated reply
FLAG_PIGGYBACK = 1 << 9  # pb_token/pb_count carry deferred acks home
FLAG_DEFER_ACK = 1 << 10  # receiver ledgers the ack instead of replying

FIELDS = (
    "type", "src", "dst", "nwords", "dst_addr", "src_addr",
    "handler", "token", "stride", "blk_words", "nblocks", "seq",
    "pb_token", "pb_count", "epoch", "crc",
)
assert len(FIELDS) == HDR_WORDS


@dataclasses.dataclass(frozen=True)
class Header:
    """Decoded header; every field is a (traced or concrete) int32 scalar."""

    type: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray
    nwords: jnp.ndarray
    dst_addr: jnp.ndarray
    src_addr: jnp.ndarray
    handler: jnp.ndarray
    token: jnp.ndarray
    stride: jnp.ndarray
    blk_words: jnp.ndarray
    nblocks: jnp.ndarray
    seq: jnp.ndarray
    pb_token: jnp.ndarray
    pb_count: jnp.ndarray
    epoch: jnp.ndarray
    crc: jnp.ndarray

    @property
    def msg_class(self):
        return self.type & _CLASS_MASK

    def flag(self, bit: int):
        return (self.type & bit) != 0


def make_type(msg_class: int, *, asynchronous=False, get=False, fifo=False,
              strided=False, vectored=False, reply=False,
              defer_ack=False) -> int:
    t = msg_class & _CLASS_MASK
    if asynchronous:
        t |= FLAG_ASYNC
    if get:
        t |= FLAG_GET
    if fifo:
        t |= FLAG_FIFO
    if strided:
        t |= FLAG_STRIDED
    if vectored:
        t |= FLAG_VECTORED
    if reply:
        t |= FLAG_REPLY
    if defer_ack:
        t |= FLAG_DEFER_ACK
    return t


def encode(**fields) -> jnp.ndarray:
    """Build a HDR_WORDS-word int32 header. Unspecified fields are zero."""
    unknown = set(fields) - set(FIELDS)
    if unknown:
        raise ValueError(f"unknown header fields: {unknown}")
    vals = [jnp.asarray(fields.get(f, 0), jnp.int32) for f in FIELDS]
    return jnp.stack(vals)


def encode_batch(n: int, **fields) -> jnp.ndarray:
    """Build ``n`` headers at once: an ``(n, HDR_WORDS)`` int32 matrix.

    Scalar fields broadcast across all rows; ``(n,)``-shaped fields are
    per-row (per-segment offsets, per-segment types, ...).  This is the
    header side of the batched >MTU segmentation plan: one matrix, one
    collective.
    """
    unknown = set(fields) - set(FIELDS)
    if unknown:
        raise ValueError(f"unknown header fields: {unknown}")
    cols = [jnp.broadcast_to(jnp.asarray(fields.get(f, 0), jnp.int32), (n,))
            for f in FIELDS]
    return jnp.stack(cols, axis=1)


def decode(hdr: jnp.ndarray) -> Header:
    if hdr.shape != (HDR_WORDS,):
        raise ValueError(f"header must be ({HDR_WORDS},), got {hdr.shape}")
    return Header(*(hdr[i] for i in range(HDR_WORDS)))


# --------------------------------------------------------------------------
# fused packets: header ++ [extra ++] payload in one int32 stream
# --------------------------------------------------------------------------

def wire_dtype_ok(dtype) -> bool:
    """Payload dtypes that bitcast losslessly onto the int32 wire."""
    return jnp.dtype(dtype).itemsize == 4


def wire_words(dtype, nwords) -> int:
    """32-bit words a payload of ``nwords`` ``dtype`` elements occupies
    on the wire.  For 32-bit dtypes this is ``nwords`` (the fused-packet
    bitcast is 1:1); sub-32-bit payloads on the split fallback ship
    ``nwords * itemsize`` bytes, i.e. fewer wire words — tx accounting
    must count what actually crosses the link, not element counts."""
    return -(-int(nwords) * jnp.dtype(dtype).itemsize // 4)


def to_wire(payload: jnp.ndarray) -> jnp.ndarray:
    """Bitcast a 32-bit payload onto int32 wire lanes (bit-exact)."""
    if payload.dtype == jnp.int32:
        return payload
    if not wire_dtype_ok(payload.dtype):
        raise TypeError(
            f"fused packets need a 32-bit payload dtype, got {payload.dtype}")
    return lax.bitcast_convert_type(payload, jnp.int32)


def from_wire(words: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`to_wire`."""
    if jnp.dtype(dtype) == jnp.int32:
        return words
    return lax.bitcast_convert_type(words, jnp.dtype(dtype))


def pack_packet(hdr: jnp.ndarray, payload: jnp.ndarray | None = None,
                extra: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fuse ``header ++ [extra ++] payload`` into one int32 packet.

    Works on single packets (``hdr``: ``(HDR_WORDS,)``) and batched
    segment stacks (``hdr``: ``(nseg, HDR_WORDS)``) alike — sections
    concatenate along the last axis.
    """
    parts = [hdr.astype(jnp.int32)]
    if extra is not None:
        parts.append(extra.astype(jnp.int32))
    if payload is not None:
        parts.append(to_wire(payload))
    return jnp.concatenate(parts, axis=-1) if len(parts) > 1 else parts[0]


def unpack_packet(pkt: jnp.ndarray, dtype, n_extra: int = 0):
    """Split a fused packet back into ``(header, [extra,] payload)``.

    ``dtype`` is the payload dtype to bitcast the trailing lanes back
    to; ``n_extra`` the length of the int32 extra section (vectored
    address lists).  Batched ``(nseg, ...)`` packets split row-wise.
    """
    hdr = pkt[..., :HDR_WORDS]
    pay = from_wire(pkt[..., HDR_WORDS + n_extra:], dtype)
    if n_extra:
        return hdr, pkt[..., HDR_WORDS:HDR_WORDS + n_extra], pay
    return hdr, pay


def reply_for(hdr: Header) -> jnp.ndarray:
    """The automatic reply: a Short AM back to the source that bumps the
    source's credit counter for ``token`` (paper Sec. III-A: "Reply
    messages are Short messages that trigger a handler function that
    increments a variable")."""
    return encode(
        type=make_type(SHORT, asynchronous=True, reply=True),
        src=hdr.dst, dst=hdr.src, token=hdr.token,
    )


def is_nop(hdr: Header):
    return hdr.msg_class == NOP


# --------------------------------------------------------------------------
# packet integrity: the crc header word (lossy-transport seal)
# --------------------------------------------------------------------------

_I_CRC = FIELDS.index("crc")


def packet_crc(pkt: jnp.ndarray) -> jnp.ndarray:
    """Integrity word for a fused packet: XOR-fold of every lane, each
    rotated left by a lane-dependent amount in [1, 31].

    The rotation makes the fold position-sensitive AND gives the
    single-bit-flip guarantee: a flip of bit ``b`` in lane ``i`` toggles
    exactly one bit of the fold (bit ``(b + rot_i) mod 32``), so the
    computed word always diverges from the stored one.  The crc lane
    itself is excluded from the fold; an all-zero NOP packet folds to 0.

    Accepts ``(..., W)`` packets; returns the ``(...,)`` int32 fold.
    """
    u = lax.bitcast_convert_type(pkt.astype(jnp.int32), jnp.uint32)
    lanes = jnp.arange(pkt.shape[-1], dtype=jnp.uint32)
    rot = (lanes % 31) + 1                       # in [1, 31]: both shifts legal
    rolled = (u << rot) | (u >> (jnp.uint32(32) - rot))
    rolled = jnp.where(lanes == _I_CRC, jnp.uint32(0), rolled)
    fold = lax.reduce(rolled, jnp.uint32(0), lax.bitwise_xor, (pkt.ndim - 1,))
    return lax.bitcast_convert_type(fold, jnp.int32)


def seal_packet(pkt: jnp.ndarray) -> jnp.ndarray:
    """Stamp the crc header word of a fused ``(..., W)`` packet (or
    segment stack).  Idempotent: the crc lane is excluded from the fold."""
    return pkt.at[..., _I_CRC].set(packet_crc(pkt))


def packet_crc_ok(pkt: jnp.ndarray) -> jnp.ndarray:
    """Per-packet bool: does the stored crc word match the fold?"""
    return pkt[..., _I_CRC] == packet_crc(pkt)
