"""Shoal: a PGAS Active-Message communication library for TPU pods.

The paper's primary contribution, adapted FPGA-cluster -> TPU pod (see
DESIGN.md Sec. 2 for the full mapping).  Public surface:

* :mod:`repro.core.am`            -- AM wire format (Short/Medium/Long,
  put/get, FIFO/memory, strided/vectored, async flag).
* :mod:`repro.core.handlers`      -- receiver-side handler table + credits.
* :mod:`repro.core.gascore`       -- the per-kernel AM engine (ingress/
  egress datapaths; the GAScore of Fig. 3).
* :mod:`repro.core.ops`           -- the user API: puts/gets/barrier/wait.
* :mod:`repro.core.collectives`   -- ring collectives built on puts (the
  trainer's ``shoal`` comm backend).
* :mod:`repro.core.humboldt`      -- two-sided 4-phase baseline.
* :mod:`repro.core.address_space` -- the partitioned global address space.
"""

from repro.core import am, collectives, gascore, handlers, humboldt, ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import PgasState, ShoalContext

__all__ = [
    "am", "collectives", "gascore", "handlers", "humboldt", "ops",
    "GlobalAddressSpace", "PgasState", "ShoalContext",
]
