"""Per-kernel PGAS state and the Shoal context.

``PgasState`` is the functional analogue of everything the GAScore /
handler thread owns per kernel in the paper: the shared-memory segment
(this kernel's partition of the global address space), the reply/credit
counter file, and a few counters we keep for the Table-I-style cost
accounting.  All Shoal ops thread it explicitly (dataflow has no mutable
runtime).

``ShoalContext`` is the trace-time configuration: which mesh axes
enumerate kernels, the transport (acked/async + packet limit), and the
handler table.  It is the analogue of a linked Shoal library instance.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.runtime.jax_compat import shard_map

from repro.core import handlers as hd
from repro.runtime.transport import Transport, TCP


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PgasState:
    """Per-kernel runtime state (a pytree; leaves are per-device arrays)."""

    segment: jnp.ndarray          # (segment_words,) shared-memory partition
    credits: jnp.ndarray          # (NUM_TOKENS,) int32 reply counters
    barrier_epoch: jnp.ndarray    # () int32
    rx_words: jnp.ndarray         # () int32 total words received
    tx_words: jnp.ndarray         # () int32 total words sent
    error: jnp.ndarray            # () int32 sticky error bits
    deferred_acks: jnp.ndarray    # (NUM_TOKENS,) int32 acks owed per link
    # deferred_acks is the receiver-side piggyback ledger: a put flagged
    # FLAG_DEFER_ACK bumps deferred_acks[token] here instead of shipping
    # a reply collective; the next packet this kernel sends over the
    # reverse link carries the count home in its pb_token/pb_count lane.

    # -- lossy-transport reliability state (PR 10) ----------------------
    # send_epoch stamps outgoing messages with a per-(sender, token)
    # sequence number; the three dedup_* arrays are the receiver's
    # redelivery ledger: dedup_epoch[t] is the last *completed* epoch on
    # token t, dedup_inflight[t] the epoch the partial-arrival bitmask
    # dedup_seen[t] (bit i = segment i arrived) belongs to.  When the
    # final segment completes the mask, dedup_epoch latches and the mask
    # drains back to zero.  retransmits counts retry rounds this kernel
    # actually re-sent in (the dynamic cost of loss — compiled CP counts
    # are static, this is not).
    send_epoch: jnp.ndarray       # (NUM_TOKENS,) int32 per-token msg counter
    dedup_epoch: jnp.ndarray      # (NUM_TOKENS,) int32 last completed epoch
    dedup_inflight: jnp.ndarray   # (NUM_TOKENS,) int32 epoch of dedup_seen
    dedup_seen: jnp.ndarray       # (NUM_TOKENS,) int32 segment-arrival bitmask
    retransmits: jnp.ndarray      # () int32 retry rounds this kernel sent in

    @staticmethod
    def make(segment_words: int, dtype=jnp.float32) -> "PgasState":
        return PgasState(
            segment=jnp.zeros((segment_words,), dtype),
            credits=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            barrier_epoch=jnp.zeros((), jnp.int32),
            rx_words=jnp.zeros((), jnp.int32),
            tx_words=jnp.zeros((), jnp.int32),
            error=jnp.zeros((), jnp.int32),
            deferred_acks=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            send_epoch=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            dedup_epoch=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            dedup_inflight=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            dedup_seen=jnp.zeros((hd.NUM_TOKENS,), jnp.int32),
            retransmits=jnp.zeros((), jnp.int32),
        )


# -- sticky error bits + host-side decode registry ---------------------------
ERR_WAIT_UNDERFLOW = 1    # wait_replies saw fewer credits than expected
ERR_CRC = 2               # a received packet failed its CRC seal
ERR_RETRY_EXHAUSTED = 4   # a reliable put ran out of retransmit rounds


class ShoalError(RuntimeError):
    """Base of host-side errors decoded from the sticky device error
    word.  ``kernels`` names the kernels that latched the bit (empty
    when the state was already reduced to a single error word)."""

    def __init__(self, message: str, kernels=()):
        self.kernels = tuple(int(k) for k in kernels)
        super().__init__(message)


class WaitUnderflowError(ShoalError):
    """A ``wait_replies`` drained more credits than the schedule issued.

    The device-side error word is sticky (kernels cannot raise), so this
    is the host-side debug surface: :func:`raise_on_error` decodes the
    error bits *and* names the offending token(s) — a drained wait
    leaves its token's credit counter negative, which is exactly the
    trace-time R3 underflow condition shoal-lint reports statically.
    """

    def __init__(self, tokens, kernels, where: str = ""):
        self.tokens = tuple(int(t) for t in tokens)
        at = f" in {where}" if where else ""
        tok = (f"token(s) {list(self.tokens)}" if self.tokens
               else "an unidentified token (counters were rebalanced)")
        kernels = tuple(int(k) for k in kernels)
        ker = (f" on kernel(s) {list(kernels)}" if kernels
               else "")
        super().__init__(
            f"ERR_WAIT_UNDERFLOW{at}: wait_replies consumed more credits "
            f"than were issued on {tok}{ker} — the threaded original "
            "would hang here; shoal-lint rule R3 catches this schedule "
            "at trace time (scripts/comm_lint.py)", kernels)


class CrcError(ShoalError):
    """A receiver saw a packet whose CRC seal failed (bit corruption on
    a lossy link).  The row was NOPed — i.e. treated as a drop — so on
    an acked transport the retransmit path recovers; the sticky bit is
    the observability surface."""


class RetryExhaustedError(ShoalError):
    """A reliable put gave up after ``max_retries`` retransmissions
    without seeing an ack.  The destination may or may not hold the
    data (the ack, not the data, may be what kept dying); the sender's
    credit was NOT granted.  `training/elastic.py` uses this bit to
    drop the kernel out of the quorum mask."""


def _build_wait_underflow(state, kernels, where):
    import numpy as np

    credits = np.asarray(jax.device_get(state.credits))
    credits = credits.reshape(-1, hd.NUM_TOKENS)
    # an over-drained wait leaves its token negative on the waiting kernel
    tokens = np.nonzero((credits < 0).any(axis=0))[0]
    return WaitUnderflowError(tokens, kernels, where=where)


def _generic_builder(name, exc):
    def build(state, kernels, where):
        kernels = tuple(int(k) for k in kernels)
        at = f" in {where}" if where else ""
        ker = f" on kernel(s) {list(kernels)}" if kernels else ""
        return exc(f"{name}{at}: sticky device error bit latched{ker} "
                   "(see repro.core.state docs for semantics)", kernels)
    return build


# bit -> (name, exception class, builder(state, kernels, where) -> exc).
# Future PRs extend via register_error_bit; raise_on_error decodes all
# registered bits, lowest bit first.
ERROR_BITS: dict[int, tuple[str, type, Any]] = {}


def register_error_bit(bit: int, name: str, exc: type = ShoalError,
                       builder=None) -> None:
    """Register a sticky error bit so :func:`raise_on_error` can decode
    and name it.  ``bit`` must be a fresh power of two."""
    if bit <= 0 or bit & (bit - 1):
        raise ValueError(f"error bit must be a power of two, got {bit}")
    if bit in ERROR_BITS:
        raise ValueError(f"error bit {bit} already registered "
                         f"as {ERROR_BITS[bit][0]}")
    ERROR_BITS[bit] = (name, exc, builder or _generic_builder(name, exc))


register_error_bit(ERR_WAIT_UNDERFLOW, "ERR_WAIT_UNDERFLOW",
                   WaitUnderflowError, _build_wait_underflow)
register_error_bit(ERR_CRC, "ERR_CRC", CrcError)
register_error_bit(ERR_RETRY_EXHAUSTED, "ERR_RETRY_EXHAUSTED",
                   RetryExhaustedError)


def error_names(err: int) -> tuple[str, ...]:
    """Names of the registered bits set in an error word."""
    return tuple(name for bit, (name, _, _) in sorted(ERROR_BITS.items())
                 if err & bit)


def raise_on_error(state: PgasState, *, where: str = "",
                   ignore: int = 0) -> PgasState:
    """Host-side debug check: raise if any kernel latched an error bit.

    Call on a state fetched back to the host (after ``spmd`` execution).
    Accepts per-kernel ``(...,)`` or stacked global ``(kernels, ...)``
    leaves; returns ``state`` unchanged when clean so it can sit inline
    in a host-side pipeline.  Every bit in the registry is decoded to
    its named exception class, lowest bit first; ``ignore`` masks bits
    the caller expects (e.g. ``ignore=ERR_CRC`` under deliberate fault
    injection).
    """
    import numpy as np

    err = np.asarray(jax.device_get(state.error)).reshape(-1)
    pending = int(np.bitwise_or.reduce(err)) & ~ignore if err.size else 0
    for bit, (name, _, build) in sorted(ERROR_BITS.items()):
        if pending & bit:
            kernels = np.nonzero(err & bit)[0] if err.size > 1 else ()
            raise build(state, kernels, where)
    if pending:
        raise ShoalError(f"unregistered error bit(s) 0x{pending:x}"
                         + (f" in {where}" if where else ""))
    return state


@dataclasses.dataclass(frozen=True)
class ShoalContext:
    """Trace-time Shoal configuration.

    Attributes:
      mesh: the device mesh (cluster).
      axes: mesh axis name(s) that enumerate kernels, row-major.
      transport: delivery semantics + packet limit (TCP/UDP analogue).
      handlers: the frozen handler table.
      segment_words: words in each kernel's segment.
    """

    mesh: Any
    axes: tuple[str, ...]
    transport: Transport = TCP
    handlers: hd.HandlerTable = dataclasses.field(default_factory=lambda: hd.DEFAULT_TABLE)
    segment_words: int = 4096

    @property
    def num_kernels(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.axes)

    def my_id(self):
        """Flattened kernel ID of the executing device (inside shard_map)."""
        return lax.axis_index(self.axes)

    def make_state(self, dtype=jnp.float32) -> PgasState:
        return PgasState.make(self.segment_words, dtype)

    def mailbox(self, pattern, **kw):
        """Per-destination coalescing mailbox over this context (the
        actor layer, :mod:`repro.actors`): N tiny sends along
        ``pattern`` flush as ONE collective."""
        from repro.actors import Mailbox  # deferred: actors imports core

        return Mailbox(self, pattern, **kw)

    def reply_mailbox(self):
        """Deferred-ack mailbox: pass as ``reply_via=`` to put ops so
        their acks coalesce into one Short AM per destination at
        flush."""
        from repro.actors import ReplyMailbox  # deferred: actors imports core

        return ReplyMailbox(self)

    def spmd(self, fn, state_spec=None, **shard_map_kwargs):
        """Wrap ``fn`` in shard_map over the kernel axes.

        Every PgasState leaf is per-kernel, i.e. sharded over the
        (flattened) kernel axes on its leading dim when viewed globally;
        we use rank-preserving specs: leading dim split over axes.
        """
        from jax.sharding import PartitionSpec as P

        spec = P(self.axes) if state_spec is None else state_spec
        return shard_map(
            fn, mesh=self.mesh, in_specs=spec, out_specs=spec, **shard_map_kwargs
        )
