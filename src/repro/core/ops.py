"""The Shoal communication API (paper Sec. III-A).

Every function here is the SPMD-collectivized form of a Shoal AM call:
all kernels execute the same line; ``pattern`` is a static list of
``(src_kernel, dst_kernel)`` pairs naming who actually communicates this
call, and kernels outside the pattern contribute NOP headers (no action,
no reply).  This is the dataflow adaptation of one-sided messaging: a
put is ONE link traversal (plus an optional auto-reply), with no
rendezvous — contrast :mod:`repro.core.humboldt`, the two-sided baseline,
which costs four.

All ops must run inside ``shard_map`` over ``ctx.axes`` (use
``ctx.spmd``).  They thread :class:`PgasState` functionally.

Wire model: one collective per link traversal.  Header and payload are
fused into a single int32 packet (:func:`repro.core.am.pack_packet`) so
a whole AM crosses a link in ONE ``ppermute`` — the wire shape of the
paper's GAScore, which parses a single AXIS stream, never two.

Message-size segmentation: AMs whose payload exceeds the transport's
``max_packet_words`` are transparently split into sequence-numbered
packets.  The paper hits this limit (9000-byte jumbo frames) in the
Jacobi application and leaves segmentation as future work (footnote 2);
we implement it with a *batched plan*: all ``nseg`` packets are stacked
into one ``(nseg, HDR_WORDS + packet_words)`` buffer, shipped with a
single collective, and absorbed by a scanned GAScore ingress.  Replies
coalesce — every segment but the last is marked async — so an acked
>MTU message costs 2 link traversals total (1 batched packet + 1 reply)
and earns ONE credit per message, not one per packet.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import trace as _lint
from repro.core import am
from repro.core import faults as flt
from repro.core import gascore as gc
from repro.core import handlers as hd
from repro.core.state import (ERR_CRC, ERR_RETRY_EXHAUSTED,
                              ERR_WAIT_UNDERFLOW, PgasState, ShoalContext)
from repro.runtime.transport import is_lossy as _transport_is_lossy

Pattern = list[tuple[int, int]]


class VectoredAliasError(ValueError):
    """A vectored put's destination address list aliases itself.

    Two blocks of ONE packet land on overlapping (or duplicate) segment
    intervals, so the result depends on the receiver's scatter order —
    the intra-packet form of the R1 race.  Deliberately order-dependent
    packets must be wrapped in ``repro.analysis.waiver(reason)``, which
    downgrades this to a waived R4 lint finding.
    """


# --------------------------------------------------------------------------
# pattern plumbing
# --------------------------------------------------------------------------

def _reverse(pattern: Pattern) -> Pattern:
    return [(d, s) for (s, d) in pattern]


def _is_sender(ctx: ShoalContext, pattern: Pattern):
    me = ctx.my_id()
    srcs = jnp.asarray([s for s, _ in pattern] or [-1], jnp.int32)
    return jnp.any(me == srcs)


def _dst_of(ctx: ShoalContext, pattern: Pattern):
    """Per-kernel destination (or -1): a trace-time table lookup."""
    table = -jnp.ones((ctx.num_kernels,), jnp.int32)
    for s, d in pattern:
        table = table.at[s].set(d)
    return table[ctx.my_id()]


def _exchange(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray,
              payload: jnp.ndarray | None, extra: jnp.ndarray | None = None):
    """One link traversal: ship ``header ++ [extra ++] payload`` along
    ``pattern`` as ONE fused packet (a single ``ppermute``), batched or
    not.  Header-only messages are already single packets.

    Returns ``(hdr, payload)`` — plus ``extra`` in the middle when an
    extra section was given.  Pure-local patterns (src == dst for every
    pair) short-circuit: no collective is issued, mirroring
    libGalapagos' internal routing for same-node kernels.  Non-32-bit
    payloads cannot bitcast onto the int32 wire and fall back to split
    collectives.
    """
    remote = [(s, d) for (s, d) in pattern if s != d]
    if not remote:
        return (hdr, extra, payload) if extra is not None else (hdr, payload)
    if payload is None and extra is None:
        return lax.ppermute(hdr, ctx.axes, pattern), None
    if payload is not None and not am.wire_dtype_ok(payload.dtype):
        hdr_r = lax.ppermute(hdr, ctx.axes, pattern)
        pay_r = lax.ppermute(payload, ctx.axes, pattern)
        if extra is None:
            return hdr_r, pay_r
        return hdr_r, lax.ppermute(extra, ctx.axes, pattern), pay_r
    n_extra = 0 if extra is None else extra.shape[-1]
    dtype = jnp.int32 if payload is None else payload.dtype
    pkt = am.pack_packet(hdr, payload, extra)
    pkt_r = lax.ppermute(pkt, ctx.axes, pattern)
    out = am.unpack_packet(pkt_r, dtype, n_extra)
    if payload is None and extra is not None:
        return out[0], out[1], None
    return out


def _mask_nonparticipants(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray):
    return jnp.where(_is_sender(ctx, pattern), hdr, jnp.zeros_like(hdr))


def _deliver_reply(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                   hdr_at_dst: am.Header, *, asynchronous: bool = False,
                   token=0, reply_via=None) -> PgasState:
    """Ship the auto-reply back along the reversed pattern and absorb it.

    For batched >MTU plans this is called once with the *final* segment's
    header — the only acked one — so a whole message costs one reply.

    Statically-async messages short-circuit here: previously an acked
    transport still shipped the (all-NOP, reply-suppressed) header back,
    wasting a collective XLA cannot DCE.  When ``reply_via`` (a reply
    mailbox, see :mod:`repro.actors`) is given, the reply is *deferred*
    instead of shipped: the mailbox records one owed credit for
    ``(pattern, token)`` and its flush returns all owed credits for a
    destination as ONE coalesced Short AM."""
    if not ctx.transport.acked or asynchronous:
        return state
    if reply_via is not None:
        reply_via.note(pattern, token)
        return state
    rep = gc.auto_reply(hdr_at_dst)
    rep_back, _ = _exchange(ctx, _reverse(pattern), rep, None)
    return gc.ingress_reply(state, am.decode(rep_back))


def _segments(nwords: int, limit: int):
    """Static segmentation plan: [(offset, words), ...]."""
    if nwords <= limit:
        return [(0, nwords)]
    out, off = [], 0
    while off < nwords:
        w = min(limit, nwords - off)
        out.append((off, w))
        off += w
    return out


def _resolve_nwords(payload, from_segment_addr, nwords, op_name: str) -> int:
    """Validate the two calling conventions and return the message size."""
    if payload is not None:
        return int(payload.size)
    if from_segment_addr is None or nwords is None:
        raise ValueError(
            f"{op_name}: pass either `payload` (FIFO variant: data from "
            "the kernel) or `from_segment_addr` AND `nwords` "
            "(memory-sourced variant: data read from the local segment)")
    return int(nwords)


def _seg_types(msg_class: int, nseg: int, *, asynchronous: bool,
               defer_ack: bool = False, **flags):
    """Per-segment type words: every segment but the last is async, so
    an acked message triggers exactly one (coalesced) reply.  With
    ``defer_ack`` the final segment asks the receiver to ledger that one
    ack for a later packet's piggyback lane instead of replying."""
    t_last = am.make_type(msg_class, asynchronous=asynchronous,
                          defer_ack=defer_ack, **flags)
    t_tail = am.make_type(msg_class, asynchronous=True, **flags)
    if nseg == 1:
        return t_last
    return jnp.where(jnp.arange(nseg) == nseg - 1, t_last, t_tail)


def _check_ack_lanes(op: str, ctx: ShoalContext, *, asynchronous,
                     defer_ack, piggyback_token, reply_via) -> None:
    """Trace-time validation of the deferred-ack / piggyback kwargs."""
    if defer_ack:
        if asynchronous:
            raise ValueError(
                f"{op}: defer_ack defers the ack of an *acked* message; "
                "asynchronous=True has no ack to defer")
        if not ctx.transport.acked:
            raise ValueError(
                f"{op}: defer_ack needs an acked transport — this "
                "transport never replies, so there is no ack to defer")
        if reply_via is not None:
            raise ValueError(
                f"{op}: defer_ack (receiver-side ledger) and reply_via "
                "(sender-side reply mailbox) are two different deferred-"
                "ack mechanisms; pick one")
    if piggyback_token is not None:
        if _lint.static_int(piggyback_token) is None:
            raise ValueError(
                f"{op}: piggyback_token must be trace-time static (the "
                "header lane and the lint schedule are built at trace "
                "time)")
        if not 0 <= int(piggyback_token) < hd.NUM_TOKENS:
            raise ValueError(
                f"{op}: piggyback_token {int(piggyback_token)} outside "
                f"[0, {hd.NUM_TOKENS})")


# header column indices used when patching encoded rows in place
_I_TYPE = am.FIELDS.index("type")
_I_TOKEN = am.FIELDS.index("token")
_I_PB_TOKEN = am.FIELDS.index("pb_token")
_I_PB_COUNT = am.FIELDS.index("pb_count")
_I_EPOCH = am.FIELDS.index("epoch")


def _attach_piggyback(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                      hdrs: jnp.ndarray, pb_token):
    """Load this sender's deferred-ack ledger for ``pb_token`` into the
    final row's piggyback lane and zero the ledger slot (senders only).

    Must run BEFORE :func:`_mask_nonparticipants`: non-senders' rows are
    zeroed afterwards anyway, and their ledger slot is left untouched.
    Returns ``(state, hdrs)``.
    """
    tok = int(pb_token)
    count = state.deferred_acks[tok]
    hdrs = hdrs.at[-1, _I_TYPE].set(hdrs[-1, _I_TYPE] | am.FLAG_PIGGYBACK)
    hdrs = hdrs.at[-1, _I_PB_TOKEN].set(tok)
    hdrs = hdrs.at[-1, _I_PB_COUNT].set(count)
    sender = _is_sender(ctx, pattern)
    ledger = state.deferred_acks.at[tok].set(
        jnp.where(sender, 0, state.deferred_acks[tok]))
    return gc.dataclasses_replace(state, deferred_acks=ledger), hdrs


# --------------------------------------------------------------------------
# lossy-transport plumbing: sealed + faulted exchanges, bounded retransmit
# --------------------------------------------------------------------------

def _require_lossless(op: str, ctx: ShoalContext) -> None:
    """Ops without a reliability protocol refuse lossy transports at
    trace time rather than silently pretending the link is perfect
    (the plain :func:`_exchange` path injects no faults)."""
    if _transport_is_lossy(ctx.transport):
        raise NotImplementedError(
            f"{op}: no retransmit/dedup protocol on a lossy transport — "
            "only put_long (and wait_replies) defend against loss; use a "
            "lossless transport or route this op over put_long")


def _lossy_recv_probs(ctx: ShoalContext, pattern: Pattern):
    """Per-receiver (drop, dup, corrupt) scalars for one traversal of
    ``pattern``: each receiver's incoming link is classified statically
    (LOCAL/ICI links stay lossless even inside a lossy collective)."""
    tbl = np.zeros((ctx.num_kernels, 3), np.float32)
    for s, d in pattern:
        tbl[d] = ctx.transport.probs_for(s, d)
    row = jnp.asarray(tbl)[ctx.my_id()]
    return row[0], row[1], row[2]


def _lossy_exchange(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                    pkt: jnp.ndarray, dtype, *, token, epoch, rnd: int,
                    direction: int):
    """One sealed link traversal over a lossy transport.

    ``pkt`` is the fused ``(nseg, HDR_WORDS + W)`` int32 stack (``W`` may
    be 0 for header-only acks).  The stack is CRC-sealed, shipped,
    faulted receiver-side (deterministically — see
    :mod:`repro.core.faults`), CRC-checked, and rows failing the check
    are NOPed with ``ERR_CRC`` latched (a corrupt packet degenerates to
    a drop the retransmit loop recovers from).  Returns
    ``(state, hdr_rows, pay_rows)`` where the stacks are ``(2 * nseg,
    ...)`` with duplicate deliveries materialised in the second half.
    """
    pkt = am.seal_packet(pkt)
    remote = [(s, d) for (s, d) in pattern if s != d]
    pkt_r = lax.ppermute(pkt, ctx.axes, pattern) if remote else pkt
    drop, dup, corrupt = _lossy_recv_probs(ctx, pattern)
    key = flt.fault_key(ctx.transport.faults, ctx.my_id(), token, epoch,
                        rnd, direction)
    delivered = flt.deliver(pkt_r, key, drop, dup, corrupt)
    ok = am.packet_crc_ok(delivered)
    state = gc.dataclasses_replace(
        state, error=state.error | jnp.where(jnp.any(~ok), ERR_CRC, 0)
        .astype(jnp.int32))
    delivered = jnp.where(ok[:, None], delivered, 0)
    hdr_rows = delivered[:, :am.HDR_WORDS]
    pay_rows = am.from_wire(delivered[:, am.HDR_WORDS:], dtype)
    return state, hdr_rows, pay_rows


def _put_long_reliable(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                       hdrs: jnp.ndarray, buf: jnp.ndarray, W: int,
                       nwords: int, token, *, acked: bool,
                       dedup: bool) -> PgasState:
    """Bounded-retransmit delivery of one sealed Long packet stack.

    Senders re-ship the (NOP-masked, so only still-pending senders pay
    wire words) stack until the receiver's ack survives the reverse
    link, up to ``max_retries`` extra rounds — the collectivized form of
    host-side retransmit with backoff: every round IS a full round-trip
    later, so waiting happens by construction, and the per-kernel
    ``retransmits`` counter records the rounds actually re-sent in (the
    dynamic cost; compiled collective counts are static).  Receivers run
    the dedup-gated ingress so redelivery is idempotent; a completed (or
    stale-redelivered final) row re-acks, covering the lost-ack case.
    On success the sender grants itself the message's ONE credit on
    ``token`` (the protocol consumed the wire ack); on exhaustion it
    latches ``ERR_RETRY_EXHAUSTED`` instead and the credit never
    appears — ``wait_replies(..., timeout=True)`` is the graceful way
    to observe that.
    """
    tok_c = jnp.clip(jnp.asarray(token, jnp.int32), 0, hd.NUM_TOKENS - 1)
    sender = _is_sender(ctx, pattern)
    epoch = state.send_epoch[tok_c] + 1
    state = gc.dataclasses_replace(
        state, send_epoch=state.send_epoch.at[tok_c].add(
            sender.astype(jnp.int32)))
    hdrs = hdrs.at[:, _I_EPOCH].set(
        jnp.where(hdrs[:, _I_TYPE] != 0, epoch, 0))
    attempts = 1 + (ctx.transport.max_retries if acked else 0)
    pending = sender
    # tx under loss counts FULL wire cost (headers + payload per data
    # round, header-only acks) so goodput = payload / tx_words is honest
    wire = am.wire_words(buf.dtype, nwords) + hdrs.shape[0] * am.HDR_WORDS
    for rnd in range(attempts):
        if rnd:
            state = gc.dataclasses_replace(
                state, retransmits=state.retransmits
                + pending.astype(jnp.int32))
        rows = jnp.where(pending, hdrs, 0)
        pay = jnp.where(pending, buf, jnp.zeros_like(buf))
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words + jnp.where(pending, wire, 0))
        state, hdr_r, pay_r = _lossy_exchange(
            ctx, state, pattern, am.pack_packet(rows, pay), buf.dtype,
            token=tok_c, epoch=epoch, rnd=rnd, direction=flt.DIR_DATA)
        state, ack_hdr = gc.ingress_reliable_stack(ctx, state, hdr_r, pay_r,
                                                   W, dedup=dedup)
        if not acked:
            return state
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words + jnp.where(
                ack_hdr[_I_TYPE] != 0, am.HDR_WORDS, 0))
        state, rep_r, _ = _lossy_exchange(
            ctx, state, _reverse(pattern), ack_hdr[None, :], jnp.int32,
            token=tok_c, epoch=epoch, rnd=rnd, direction=flt.DIR_REPLY)
        t_col = rep_r[:, _I_TYPE]
        got = jnp.any(((t_col & am._CLASS_MASK) == am.SHORT)
                      & ((t_col & am.FLAG_REPLY) != 0)
                      & (rep_r[:, _I_TOKEN] == tok_c))
        pending = pending & ~got
    delivered = sender & ~pending
    return gc.dataclasses_replace(
        state,
        credits=state.credits.at[tok_c].add(delivered.astype(jnp.int32)),
        error=state.error | jnp.where(pending, ERR_RETRY_EXHAUSTED, 0)
        .astype(jnp.int32))


# --------------------------------------------------------------------------
# Short AMs
# --------------------------------------------------------------------------

def put_short(ctx: ShoalContext, state: PgasState, pattern: Pattern, *,
              handler=hd.H_ADD, arg=1, token=0,
              asynchronous: bool = False, reply_via=None) -> PgasState:
    """Short AM: signal the destination (no payload).

    The handler runs on the destination's credit word ``token`` with
    ``arg``; the default (H_ADD, 1) is a counting semaphore.
    """
    _require_lossless("put_short", ctx)
    h_s, a_s, t_s = (_lint.static_int(handler), _lint.static_int(arg),
                     _lint.static_int(token))
    grants = ((t_s, a_s),) if (h_s == hd.H_ADD and a_s is not None
                               and t_s is not None) else ()
    tag = _lint.emit(
        "put_short", pattern, token=t_s,
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        credit_grants=grants, handler=h_s, segment_words=ctx.segment_words)
    with _lint.scope(tag):
        t = am.make_type(am.SHORT, asynchronous=asynchronous)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        handler=handler, token=token, dst_addr=arg)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        hdr_r, _ = _exchange(ctx, pattern, hdr, None)
        h = am.decode(hdr_r)
        state = gc.ingress_short(ctx, state, h)
        return _deliver_reply(ctx, state, pattern, h,
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


# --------------------------------------------------------------------------
# Medium AMs (payload -> destination kernel)
# --------------------------------------------------------------------------

def put_medium(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
               pattern: Pattern, *, handler=hd.H_NOP, token=0,
               asynchronous: bool = False, from_segment_addr=None,
               nwords: int | None = None, reply_via=None):
    """Medium AM: point-to-point payload straight to the destination
    kernel (returned value).  ``from_segment_addr`` selects the
    memory-sourced variant (payload read from the local segment by the
    GAScore at that address, ``nwords`` long, i.e. the non-FIFO case);
    default is the FIFO variant with ``payload`` from the kernel.

    Returns ``(state, delivered)``; ``delivered`` is zeros on kernels
    that receive nothing this call.  >MTU payloads ship as one batched
    packet stack: a single collective plus (if acked) a single
    coalesced reply.
    """
    _require_lossless("put_medium", ctx)
    nwords = _resolve_nwords(payload, from_segment_addr, nwords, "put_medium")
    fifo = from_segment_addr is None
    tag = _lint.emit(
        "put_medium", pattern, token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        detail={"nwords": nwords})
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.MEDIUM, nseg, asynchronous=asynchronous,
                            fifo=fifo),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            handler=handler, token=token,
            src_addr=0 if fifo else from_segment_addr + offs, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload if fifo else None, W)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern),
                      am.wire_words(state.segment.dtype, nwords), 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state, delivered = gc.ingress_medium_batch(state, hdr_r, pay_r, W)
        state = _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                               asynchronous=asynchronous, token=token,
                               reply_via=reply_via)
        return state, delivered[:nwords]


# --------------------------------------------------------------------------
# Long AMs (payload -> destination shared memory)
# --------------------------------------------------------------------------

def put_long(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
             pattern: Pattern, dst_addr, *, handler=hd.H_WRITE, token=0,
             asynchronous: bool = False, from_segment_addr=None,
             nwords: int | None = None, reply_via=None,
             defer_ack: bool = False, piggyback_token=None,
             dedup: bool = True) -> PgasState:
    """Long AM: one-sided put into the destination kernel's segment at
    ``dst_addr``, applied through ``handler`` (H_WRITE = plain put,
    H_ADD = remote accumulate, ...).  FIFO variant when ``payload`` is
    given; memory-sourced variant when ``from_segment_addr`` is.

    >MTU payloads ship as one ``(nseg, HDR+W)`` packet stack — a single
    collective — and are absorbed by a scanned GAScore ingress; an acked
    message earns ONE credit (the final segment carries the ack).

    ``defer_ack=True`` removes even the reply collective: the receiver
    ledgers the owed ack (``state.deferred_acks[token]``) and a later
    packet crossing the reverse link carries it home — either another
    put with ``piggyback_token=token`` or :func:`drain_deferred_acks`.
    ``piggyback_token=t`` loads THIS packet's piggyback lane with the
    sender's ledgered acks for ``t`` (acks this kernel owes for puts it
    *received* over the link this packet now travels in reverse).

    On a lossy transport (:class:`repro.runtime.transport.LossyTransport`
    with a non-zero fault model) the put runs the reliability protocol
    instead: packets are CRC-sealed and epoch-stamped, receivers dedup
    redelivery, and (if acked) senders retransmit up to ``max_retries``
    rounds before latching ``ERR_RETRY_EXHAUSTED`` — see
    :func:`_put_long_reliable`.  ``dedup=False`` disables the receiver
    ledger (shoal-lint rule R5 flags that combination).  The ack-lane
    machinery (defer_ack / piggyback / reply_via) presumes a lossless
    reply and is rejected on lossy transports.
    """
    nwords = _resolve_nwords(payload, from_segment_addr, nwords, "put_long")
    fifo = from_segment_addr is None
    _check_ack_lanes("put_long", ctx, asynchronous=asynchronous,
                     defer_ack=defer_ack, piggyback_token=piggyback_token,
                     reply_via=reply_via)
    lossy = _transport_is_lossy(ctx.transport)
    acked = ctx.transport.acked and not asynchronous
    if lossy and (defer_ack or piggyback_token is not None
                  or reply_via is not None):
        raise NotImplementedError(
            "put_long: deferred/piggybacked acks assume a lossless reply "
            "path and cannot ride a lossy transport (a dropped piggyback "
            "lane would strand the ledger); use plain acked puts")
    tag = _lint.emit(
        "put_long", pattern,
        writes=(_lint.Interval(_lint.static_int(dst_addr), nwords),),
        token=_lint.static_int(token),
        acked=acked,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        defer_ack=defer_ack,
        piggyback_token=(None if piggyback_token is None
                         else int(piggyback_token)),
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        lossy=lossy,
        retries=(ctx.transport.max_retries if lossy and acked else 0),
        dedup=dedup if lossy else True)
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        if lossy and nseg > 31:
            raise NotImplementedError(
                f"put_long: {nseg} segments > 31 — the dedup ledger's "
                "arrival bitmask is one int32 per token; raise the MTU or "
                "split the message")
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.LONG, nseg, asynchronous=asynchronous,
                            defer_ack=defer_ack, fifo=fifo),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            dst_addr=dst_addr + offs,
            src_addr=0 if fifo else from_segment_addr + offs,
            handler=handler, token=token, seq=offs)
        if piggyback_token is not None:
            state, hdrs = _attach_piggyback(ctx, state, pattern, hdrs,
                                            piggyback_token)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload if fifo else None, W)
        if lossy:
            if not am.wire_dtype_ok(buf.dtype):
                raise NotImplementedError(
                    "put_long: the lossy-transport seal covers the fused "
                    "int32 packet; sub-32-bit payloads use the split "
                    "fallback and have no integrity protection yet")
            return _put_long_reliable(ctx, state, pattern, hdrs, buf, W,
                                      nwords, token, acked=acked,
                                      dedup=dedup)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern),
                      am.wire_words(state.segment.dtype, nwords), 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state = gc.ingress_long_batch(ctx, state, hdr_r, pay_r, W)
        # the final row is the only non-async one: it carries the ack
        # lanes (defer ledger bump and/or piggybacked ack grant)
        state = gc.ingress_ack_lanes(state, am.decode(hdr_r[-1]))
        return _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                              asynchronous=asynchronous or defer_ack,
                              token=token, reply_via=reply_via)


def group_disjoint_patterns(patterns: list[Pattern]) -> list[list[int]]:
    """Greedily group patterns into valid union permutations.

    Two patterns may share one ``ppermute`` only when BOTH their source
    sets and their destination sets are disjoint — ``lax.ppermute``
    allows each kernel to send at most one buffer and receive at most
    one.  Disjoint rings (even->odd and odd->even) merge; Jacobi's
    up/down halo pair does not (every interior kernel sends on both
    links), which is exactly why its steady state needs reply
    piggybacking rather than more merging.  Returns index lists into
    ``patterns``, first-fit in input order.
    """
    groups: list[list[int]] = []
    gsrcs: list[set[int]] = []
    gdsts: list[set[int]] = []
    for i, pat in enumerate(patterns):
        srcs = {s for s, _ in pat}
        dsts = {d for _, d in pat}
        for g in range(len(groups)):
            if not (gsrcs[g] & srcs) and not (gdsts[g] & dsts):
                groups[g].append(i)
                gsrcs[g] |= srcs
                gdsts[g] |= dsts
                break
        else:
            groups.append([i])
            gsrcs.append(set(srcs))
            gdsts.append(set(dsts))
    return groups


def _counted_group_reply(ctx: ShoalContext, state: PgasState, union: Pattern,
                         hdr_r: jnp.ndarray, *, token=None,
                         classes: tuple[int, ...] | None = (am.LONG,)
                         ) -> PgasState:
    """ONE reply collective for a whole grouped packet stack.

    Each receiver folds over the rows it just absorbed, counts the acked
    ones (non-async, non-reply, non-deferred — exactly one per message,
    since tail segments are async), and ships the count back as a Short
    H_ADD over the reversed union.  The union permutation guarantees a
    kernel received rows from at most one sender, so the dynamic token
    read off the acked rows is single-valued per receiver; a static
    ``token`` overrides it (mailbox flushes ack on the mailbox token
    regardless of per-row tokens).  ``classes`` restricts which message
    classes count (``None`` = any non-NOP row).
    """
    t_col = hdr_r[:, _I_TYPE]
    cls = t_col & am._CLASS_MASK
    if classes is None:
        is_cls = cls != am.NOP
    else:
        is_cls = jnp.zeros(t_col.shape, bool)
        for c in classes:
            is_cls = is_cls | (cls == c)
    needs = is_cls & ((t_col & (am.FLAG_ASYNC | am.FLAG_REPLY
                                | am.FLAG_DEFER_ACK)) == 0)
    cnt = jnp.sum(needs.astype(jnp.int32))
    tok = (jnp.max(jnp.where(needs, hdr_r[:, _I_TOKEN], 0))
           if token is None else token)
    rev = _reverse(union)
    hdr = am.encode(type=am.make_type(am.SHORT, asynchronous=True),
                    src=ctx.my_id(), dst=_dst_of(ctx, rev),
                    handler=hd.H_ADD, token=tok, dst_addr=cnt)
    hdr = _mask_nonparticipants(ctx, rev, hdr)
    hdr_back, _ = _exchange(ctx, rev, hdr, None)
    return gc.ingress_short(ctx, state, am.decode(hdr_back))


def put_long_multi(ctx: ShoalContext, state: PgasState, items, *,
                   handler=hd.H_WRITE, token=0, tokens=None,
                   asynchronous: bool = False, defer_ack: bool = False,
                   piggyback_tokens=None, reply_via=None) -> PgasState:
    """Multi-destination Long put: batch several puts over different
    patterns into as few collectives as possible.

    ``items`` is ``[(payload, pattern, dst_addr), ...]`` (FIFO variant).
    Patterns whose source AND destination sets are disjoint form a valid
    union permutation: their per-destination ``(nseg, HDR+W)`` packet
    stacks concatenate and the whole group crosses the links as ONE
    ``ppermute``, absorbed by the scanned mixed-class
    :func:`repro.core.gascore.ingress_stack`.  Patterns that share a
    source or destination (Jacobi's up+down halo pair) cannot legally
    merge and land in separate groups — see
    :func:`group_disjoint_patterns`.

    Ack accounting: one credit per item, on that item's token
    (``tokens`` gives per-item tokens; default all ``token``).  On the
    immediate-ack path each group costs ONE extra reply collective
    total (:func:`_counted_group_reply`), not one per item.  With
    ``defer_ack=True`` no reply collective exists at all: receivers
    ledger the acks and ``piggyback_tokens[i]`` loads item *i*'s final
    packet with the sender's ledgered acks for that token (the steady-
    state loop shape: each direction's data packet carries the opposite
    direction's acks home).

    Destination intervals that overlap across items sharing a
    destination kernel raise :class:`VectoredAliasError` — the landed
    value would depend on stack order — unless the call is wrapped in
    ``repro.analysis.waiver(reason)``.
    """
    if not items:
        raise ValueError("put_long_multi: empty item list")
    _require_lossless("put_long_multi", ctx)
    k = len(items)
    toks = list(tokens) if tokens is not None else [token] * k
    if len(toks) != k:
        raise ValueError(
            f"put_long_multi: {k} items but {len(toks)} tokens")
    pbs = (list(piggyback_tokens) if piggyback_tokens is not None
           else [None] * k)
    if len(pbs) != k:
        raise ValueError(
            f"put_long_multi: {k} items but {len(pbs)} piggyback_tokens")
    for pb in pbs:
        _check_ack_lanes("put_long_multi", ctx, asynchronous=asynchronous,
                         defer_ack=defer_ack, piggyback_token=pb,
                         reply_via=reply_via)
    parsed = []
    for i, item in enumerate(items):
        try:
            payload, pattern, dst_addr = item
        except (TypeError, ValueError):
            raise ValueError(
                "put_long_multi: items are (payload, pattern, dst_addr) "
                f"triples; item {i} is {item!r}") from None
        if payload is None:
            raise ValueError(
                f"put_long_multi: item {i} has no payload (only the "
                "FIFO variant batches; use put_long for memory-sourced)")
        pat = [(int(s), int(d)) for s, d in pattern]
        parsed.append((payload, pat, dst_addr, int(payload.size)))
    ivs = [_lint.Interval(_lint.static_int(a), nw)
           for _, _, a, nw in parsed]
    alias = None
    for i in range(k):
        for j in range(i + 1, k):
            common = ({d for _, d in parsed[i][1]}
                      & {d for _, d in parsed[j][1]})
            if common and ivs[i].known and ivs[j].known \
                    and ivs[i].overlaps(ivs[j]):
                alias = (i, j, sorted(common))
                break
        if alias:
            break
    if alias is not None and _lint.current_waiver() is None:
        i, j, common = alias
        raise VectoredAliasError(
            f"put_long_multi: items {i} ({ivs[i]}) and {j} ({ivs[j]}) "
            f"overlap at destination kernel(s) {common} within one "
            "batched call, so the landed value depends on stack order "
            "(silent last-writer-wins). Give the items disjoint "
            "intervals, or wrap the call in "
            "repro.analysis.waiver(reason) if the overlap is deliberate.")
    groups = group_disjoint_patterns([p for _, p, _, _ in parsed])
    acked = ctx.transport.acked and not asynchronous
    mtu = ctx.transport.max_packet_words
    for gi, grp in enumerate(groups):
        # one packet width for the whole group so stacks concatenate;
        # re-planning every item at this width keeps egress's pad +
        # reshape exact (all rows but an item's last are full)
        W = min(mtu, max(parsed[i][3] for i in grp))
        group_tag = None
        hdr_rows, pay_rows, union = [], [], []
        for i in grp:
            payload, pat, dst_addr, nw = parsed[i]
            tag = _lint.emit(
                "put_long_multi", pat, writes=(ivs[i],),
                token=_lint.static_int(toks[i]), acked=acked,
                asynchronous=asynchronous,
                deferred_reply=reply_via is not None,
                defer_ack=defer_ack,
                piggyback_token=None if pbs[i] is None else int(pbs[i]),
                handler=_lint.static_int(handler),
                segment_words=ctx.segment_words,
                self_overlap=alias is not None and i in alias[:2],
                detail={"group": gi, "item": i, "n_items": k})
            group_tag = group_tag or tag
            union.extend(pat)
            segs = _segments(nw, W)
            nseg = len(segs)
            offs = jnp.asarray([o for o, _ in segs], jnp.int32)
            ws = jnp.asarray([w for _, w in segs], jnp.int32)
            with _lint.scope(tag):
                hdrs = am.encode_batch(
                    nseg,
                    type=_seg_types(am.LONG, nseg,
                                    asynchronous=asynchronous,
                                    defer_ack=defer_ack, fifo=True),
                    src=ctx.my_id(), dst=_dst_of(ctx, pat), nwords=ws,
                    dst_addr=dst_addr + offs, handler=handler,
                    token=toks[i], seq=offs)
                if pbs[i] is not None:
                    state, hdrs = _attach_piggyback(ctx, state, pat,
                                                    hdrs, pbs[i])
                hdrs = _mask_nonparticipants(ctx, pat, hdrs)
                pay_rows.append(gc.egress_batch(ctx, state, hdrs,
                                                payload, W))
                hdr_rows.append(hdrs)
                state = gc.dataclasses_replace(
                    state, tx_words=state.tx_words +
                    jnp.where(_is_sender(ctx, pat),
                              am.wire_words(state.segment.dtype, nw), 0))
        union = sorted(set(union))
        with _lint.scope(group_tag):
            hdr_r, pay_r = _exchange(ctx, union,
                                     jnp.concatenate(hdr_rows, axis=0),
                                     jnp.concatenate(pay_rows, axis=0))
            state = gc.ingress_stack(ctx, state, hdr_r, pay_r, W)
            if acked and not defer_ack:
                if reply_via is not None:
                    for i in grp:
                        reply_via.note(parsed[i][1], toks[i])
                else:
                    state = _counted_group_reply(ctx, state, union, hdr_r)
    return state


def drain_deferred_acks(ctx: ShoalContext, state: PgasState,
                        pattern: Pattern, token) -> PgasState:
    """Ship this kernel's residual deferred-ack ledger for ``token``
    home as one header-only Short H_ADD along ``pattern`` (1
    collective) and zero the ledger slot.

    Loop exit for the piggyback protocol: in steady state, iteration
    *k*'s acks ride iteration *k+1*'s reverse-link data packet, so when
    the loop ends the final iteration's acks are still ledgered at the
    receivers.  ``pattern`` must be the REVERSE link of the defer-acked
    puts: its senders are the kernels holding the ledger, its
    destinations the kernels whose ``wait_replies(token, ...)`` is
    still owed.  The count rides in the handler-arg word (dynamic), so
    one drain balances any number of outstanding puts.
    """
    _require_lossless("drain_deferred_acks", ctx)
    t_s = _lint.static_int(token)
    if t_s is None:
        raise ValueError("drain_deferred_acks: token must be trace-time "
                         "static (it names the ledger slot)")
    if not 0 <= t_s < hd.NUM_TOKENS:
        raise ValueError(
            f"drain_deferred_acks: token {t_s} outside [0, {hd.NUM_TOKENS})")
    tag = _lint.emit("drain_deferred_acks", pattern, token=t_s,
                     acked=False, asynchronous=True, drains_deferred=True,
                     handler=hd.H_ADD, segment_words=ctx.segment_words)
    with _lint.scope(tag):
        count = state.deferred_acks[t_s]
        hdr = am.encode(type=am.make_type(am.SHORT, asynchronous=True),
                        src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        handler=hd.H_ADD, token=token, dst_addr=count)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        sender = _is_sender(ctx, pattern)
        ledger = state.deferred_acks.at[t_s].set(
            jnp.where(sender, 0, state.deferred_acks[t_s]))
        state = gc.dataclasses_replace(state, deferred_acks=ledger)
        hdr_r, _ = _exchange(ctx, pattern, hdr, None)
        return gc.ingress_short(ctx, state, am.decode(hdr_r))


def _strides_may_overlap(stride, blk_words: int, nblocks: int) -> bool:
    """Static overlap detection for strided puts: True when consecutive
    blocks can alias (``|stride| < blk_words``).  A traced stride is
    conservatively treated as overlapping — the caller can override with
    the ``overlap`` kwarg when it knows better."""
    if nblocks <= 1:
        return False
    try:
        return abs(int(stride)) < blk_words
    except Exception:  # traced stride: cannot prove blocks disjoint
        return True


def put_long_strided(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray,
                     pattern: Pattern, dst_addr, stride, *,
                     blk_words: int, nblocks: int, handler=hd.H_WRITE,
                     token=0, asynchronous: bool = False,
                     overlap: bool | None = None, reply_via=None) -> PgasState:
    """Strided Long put: ``nblocks`` blocks of ``blk_words`` land at
    ``dst_addr + i*stride`` (THeGASNet's strided access, carried forward
    by the paper).  ``payload`` is the packed (nblocks*blk_words,)
    buffer — see :mod:`repro.kernels.am_pack` for the packing hot path.
    Block geometry is static; stride may be traced.

    >MTU messages segment at block granularity into one batched packet
    stack (single collective, one coalesced reply).

    Aliasing strides (``|stride| < blk_words``) are detected statically
    and ingress switches to the block-sequential scan that preserves
    last-writer-wins ordering; a traced stride is conservatively treated
    as aliasing.  ``overlap`` overrides the detection either way.
    """
    _require_lossless("put_long_strided", ctx)
    ordered = (_strides_may_overlap(stride, blk_words, nblocks)
               if overlap is None else bool(overlap))
    nwords = blk_words * nblocks
    base_s, stride_s = _lint.static_int(dst_addr), _lint.static_int(stride)
    if base_s is not None and stride_s is not None:
        w_ivs = tuple(_lint.Interval(base_s + i * stride_s, blk_words)
                      for i in range(nblocks))
    else:
        w_ivs = (_lint.Interval(None, nwords),)
    may_alias = _strides_may_overlap(stride, blk_words, nblocks)
    tag = _lint.emit(
        "put_long_strided", pattern, writes=w_ivs,
        token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        ordered_ingress=ordered, self_overlap=may_alias and not ordered,
        detail={"stride": stride_s, "blk_words": blk_words,
                "nblocks": nblocks})
    with _lint.scope(tag):
        # blocks per packet; >MTU plans segment at block granularity
        per = max(1, ctx.transport.max_packet_words // blk_words)
        nseg = -(-nblocks // per)
        nb = jnp.minimum(per,
                         nblocks - per * jnp.arange(nseg)).astype(jnp.int32)
        W = min(per, nblocks) * blk_words
        offs = jnp.arange(nseg, dtype=jnp.int32) * (per * blk_words)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.LONG, nseg, asynchronous=asynchronous,
                            fifo=True, strided=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern),
            nwords=nb * blk_words,
            dst_addr=dst_addr + jnp.arange(nseg) * per * stride,
            handler=handler, token=token, stride=stride,
            blk_words=blk_words, nblocks=nb, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload, W)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern),
                      am.wire_words(state.segment.dtype, nwords), 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state = gc.ingress_strided_batch(ctx, state, hdr_r, pay_r, blk_words,
                                         min(per, nblocks), ordered)
        return _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


def put_long_vectored(ctx: ShoalContext, state: PgasState,
                      blocks: list[jnp.ndarray], pattern: Pattern,
                      dst_addrs, *, handler=hd.H_WRITE, token=0,
                      asynchronous: bool = False, reply_via=None) -> PgasState:
    """Vectored Long put: ``blocks[i]`` lands at ``dst_addrs[i]``.  One
    AM on the wire: the destination address list rides inside the fused
    packet as an extra int32 section (``header ++ addrs ++ payload``),
    so the whole message is a single collective; the receiver scatters.
    Block sizes are static; addresses may be traced."""
    _require_lossless("put_long_vectored", ctx)
    try:
        n_addrs = len(dst_addrs)
    except TypeError:
        n_addrs = int(jnp.shape(jnp.asarray(dst_addrs))[0])
    if n_addrs != len(blocks):
        # jnp indexing clamps, so a short address list would silently
        # alias trailing blocks onto the last address
        raise ValueError(
            f"put_long_vectored: {len(blocks)} blocks but {n_addrs} "
            "dst_addrs — one destination address per block")
    nwords = sum(int(b.size) for b in blocks)
    if nwords + len(blocks) > ctx.transport.max_packet_words:
        raise ValueError(
            f"put_long_vectored: {nwords} payload words + {len(blocks)} "
            f"in-packet addresses exceed the transport MTU "
            f"({ctx.transport.max_packet_words} words); vectored puts do "
            "not segment — split the block list across messages")
    sizes = [int(b.size) for b in blocks]
    ivs = _lint.intervals_for_blocks(list(dst_addrs), sizes)
    alias = next(((i, j) for i in range(len(ivs))
                  for j in range(i + 1, len(ivs))
                  if ivs[i].known and ivs[j].known
                  and ivs[i].overlaps(ivs[j])), None)
    if alias is not None and _lint.current_waiver() is None:
        i, j = alias
        raise VectoredAliasError(
            f"put_long_vectored: destination blocks {i} ({ivs[i]}) and "
            f"{j} ({ivs[j]}) overlap inside one packet, so the landed "
            "value depends on the receiver's scatter order (duplicate "
            "addresses are the degenerate case). Give each block a "
            "disjoint interval, or wrap the call in "
            "repro.analysis.waiver(reason) if the overlap is deliberate.")
    tag = _lint.emit(
        "put_long_vectored", pattern, writes=ivs,
        token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        self_overlap=alias is not None,
        detail={} if alias is None else
        {"alias": f"blocks {alias[0]} and {alias[1]} overlap"})
    with _lint.scope(tag):
        payload = jnp.concatenate([b.reshape(-1) for b in blocks])
        t = am.make_type(am.LONG, asynchronous=asynchronous, fifo=True,
                         vectored=True)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=nwords, handler=handler, token=token,
                        nblocks=len(blocks))
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        buf = gc.egress(ctx, state, am.decode(hdr), payload, nwords)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern),
                      am.wire_words(state.segment.dtype, nwords), 0))
        addrs = jnp.asarray(dst_addrs, jnp.int32)
        hdr_r, addrs_r, pay_r = _exchange(ctx, pattern, hdr, buf, extra=addrs)
        h = am.decode(hdr_r)
        off = 0
        for i, b in enumerate(blocks):
            w = int(b.size)
            sub_hdr = am.Header(
                type=h.type, src=h.src, dst=h.dst,
                nwords=jnp.asarray(w, jnp.int32),
                dst_addr=addrs_r[i], src_addr=h.src_addr, handler=h.handler,
                token=h.token, stride=h.stride, blk_words=h.blk_words,
                nblocks=h.nblocks, seq=h.seq, pb_token=h.pb_token,
                pb_count=h.pb_count, epoch=h.epoch, crc=h.crc)
            state = gc.ingress_long(ctx, state, sub_hdr,
                                    lax.dynamic_slice(pay_r, (off,), (w,)), w)
            off += w
        return _deliver_reply(ctx, state, pattern, h,
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


# --------------------------------------------------------------------------
# Gets (one round trip: request header out, data back)
# --------------------------------------------------------------------------

def get_medium(ctx: ShoalContext, state: PgasState, pattern: Pattern,
               src_addr, nwords: int, *, token=0):
    """Medium get: fetch ``nwords`` at ``src_addr`` in the *destination*
    kernel's segment, delivered to the requesting kernel.  Returns
    ``(state, data)``.  The data return doubles as the reply (credits
    bump ONCE per message, on the final segment).  >MTU gets batch all
    request headers into one collective and the whole response into a
    second: 2 link traversals regardless of segment count."""
    _require_lossless("get_medium", ctx)
    tag = _lint.emit(
        "get_medium", pattern,
        reads=(_lint.Interval(_lint.static_int(src_addr), int(nwords)),),
        token=_lint.static_int(token), acked=True,
        segment_words=ctx.segment_words)
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg, type=am.make_type(am.MEDIUM, get=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            src_addr=src_addr + offs, token=token, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        hdr_r, _ = _exchange(ctx, pattern, hdrs, None)
        state, resp_rows, data_rows = gc.serve_get_batch(ctx, state, hdr_r, W)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_rows,
                                        data_rows)
        state = gc.ingress_reply(state, am.decode(back_hdr[-1]))
        state, data = gc.ingress_medium_batch(state, back_hdr, back_data, W)
        return state, data[:nwords]


def get_long(ctx: ShoalContext, state: PgasState, pattern: Pattern,
             src_addr, nwords: int, dst_addr, *, handler=hd.H_WRITE,
             token=0) -> PgasState:
    """Long get: fetch remote segment words into the *local* segment at
    ``dst_addr`` (one-sided read).  Same batched 2-traversal wire plan
    as :func:`get_medium`; one credit per message."""
    _require_lossless("get_long", ctx)
    tag = _lint.emit(
        "get_long", pattern,
        reads=(_lint.Interval(_lint.static_int(src_addr), int(nwords)),),
        token=_lint.static_int(token), acked=True,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        detail={"local_dst_addr": _lint.static_int(dst_addr)})
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg, type=am.make_type(am.LONG, get=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            src_addr=src_addr + offs, dst_addr=dst_addr + offs,
            token=token, handler=handler, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        hdr_r, _ = _exchange(ctx, pattern, hdrs, None)
        state, resp_rows, data_rows = gc.serve_get_batch(ctx, state, hdr_r, W)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_rows,
                                        data_rows)
        state = gc.ingress_reply(state, am.decode(back_hdr[-1]))
        # land in local segment through the handler (class LONG on the wire)
        is_rep = (back_hdr[:, 0] & am.FLAG_REPLY) != 0
        land_rows = back_hdr.at[:, 0].set(
            jnp.where(is_rep, am.LONG, am.NOP).astype(jnp.int32))
        return gc.ingress_long_batch(ctx, state, land_rows, back_data, W)


# --------------------------------------------------------------------------
# synchronization
# --------------------------------------------------------------------------

def barrier(ctx: ShoalContext, state: PgasState) -> PgasState:
    """Global barrier over all kernels (paper Sec. III: "barriers for
    synchronization").  A psum of a unit scalar is the dataflow barrier:
    no kernel's successor ops can be scheduled before every kernel's
    contribution arrives.  The barrier epoch counts completions."""
    tag = _lint.emit("barrier", [])
    with _lint.scope(tag):
        arrived = lax.psum(jnp.ones((), jnp.int32), ctx.axes)
        epoch = state.barrier_epoch + (arrived // arrived)  # data-dependent
        return gc.dataclasses_replace(state, barrier_epoch=epoch)


def wait_replies(ctx: ShoalContext, state: PgasState, token, n, *,
                 timeout: bool = False) -> PgasState:
    """Wait for ``n`` replies on ``token`` then consume them.

    Replies coalesce across >MTU segmentation, so ``n`` counts
    *messages*, not packets.  In SPMD dataflow, arrival is guaranteed by
    data dependence, so this is bookkeeping: it drains ``n`` credits and
    raises a sticky error bit if fewer than ``n`` were present — the
    observable equivalent of a hang in the threaded original (tests
    assert on it).  On the host, :func:`repro.core.state.raise_on_error`
    converts the bit into a named :class:`~repro.core.state.
    WaitUnderflowError` carrying the offending token id(s).

    ``timeout=True`` is the lossy-transport path: a reliable put whose
    retransmits were exhausted never granted its credit, so a plain
    wait would latch ``ERR_WAIT_UNDERFLOW`` forever on top of the
    already-latched ``ERR_RETRY_EXHAUSTED``.  The timeout path instead
    drains ``min(have, n)`` — the waits that *did* complete — and latches
    nothing: the threaded original's bounded-timeout wait, where giving
    up is a normal outcome the caller inspects (via the error word)
    rather than a schedule bug.
    """
    tag = _lint.emit("wait_replies", [], token=_lint.static_int(token),
                     wait_n=_lint.static_int(n), timeout=timeout)
    with _lint.scope(tag):
        token = jnp.clip(jnp.asarray(token, jnp.int32), 0, hd.NUM_TOKENS - 1)
        have = state.credits[token]
        if timeout:
            take = jnp.minimum(have, jnp.asarray(n, jnp.int32))
            take = jnp.maximum(take, 0)
            credits = hd.drain_credits(state.credits, token, take)
            return gc.dataclasses_replace(state, credits=credits)
        err = jnp.where(have < n, ERR_WAIT_UNDERFLOW, 0).astype(jnp.int32)
        credits = hd.drain_credits(state.credits, token, n)
        return gc.dataclasses_replace(state, credits=credits,
                                      error=state.error | err)
