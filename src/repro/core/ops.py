"""The Shoal communication API (paper Sec. III-A).

Every function here is the SPMD-collectivized form of a Shoal AM call:
all kernels execute the same line; ``pattern`` is a static list of
``(src_kernel, dst_kernel)`` pairs naming who actually communicates this
call, and kernels outside the pattern contribute NOP headers (no action,
no reply).  This is the dataflow adaptation of one-sided messaging: a
put is ONE link traversal (plus an optional auto-reply), with no
rendezvous — contrast :mod:`repro.core.humboldt`, the two-sided baseline,
which costs four.

All ops must run inside ``shard_map`` over ``ctx.axes`` (use
``ctx.spmd``).  They thread :class:`PgasState` functionally.

Wire model: one collective per link traversal.  Header and payload are
fused into a single int32 packet (:func:`repro.core.am.pack_packet`) so
a whole AM crosses a link in ONE ``ppermute`` — the wire shape of the
paper's GAScore, which parses a single AXIS stream, never two.

Message-size segmentation: AMs whose payload exceeds the transport's
``max_packet_words`` are transparently split into sequence-numbered
packets.  The paper hits this limit (9000-byte jumbo frames) in the
Jacobi application and leaves segmentation as future work (footnote 2);
we implement it with a *batched plan*: all ``nseg`` packets are stacked
into one ``(nseg, HDR_WORDS + packet_words)`` buffer, shipped with a
single collective, and absorbed by a scanned GAScore ingress.  Replies
coalesce — every segment but the last is marked async — so an acked
>MTU message costs 2 link traversals total (1 batched packet + 1 reply)
and earns ONE credit per message, not one per packet.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.analysis import trace as _lint
from repro.core import am
from repro.core import gascore as gc
from repro.core import handlers as hd
from repro.core.state import ERR_WAIT_UNDERFLOW, PgasState, ShoalContext

Pattern = list[tuple[int, int]]


class VectoredAliasError(ValueError):
    """A vectored put's destination address list aliases itself.

    Two blocks of ONE packet land on overlapping (or duplicate) segment
    intervals, so the result depends on the receiver's scatter order —
    the intra-packet form of the R1 race.  Deliberately order-dependent
    packets must be wrapped in ``repro.analysis.waiver(reason)``, which
    downgrades this to a waived R4 lint finding.
    """


# --------------------------------------------------------------------------
# pattern plumbing
# --------------------------------------------------------------------------

def _reverse(pattern: Pattern) -> Pattern:
    return [(d, s) for (s, d) in pattern]


def _is_sender(ctx: ShoalContext, pattern: Pattern):
    me = ctx.my_id()
    srcs = jnp.asarray([s for s, _ in pattern] or [-1], jnp.int32)
    return jnp.any(me == srcs)


def _dst_of(ctx: ShoalContext, pattern: Pattern):
    """Per-kernel destination (or -1): a trace-time table lookup."""
    table = -jnp.ones((ctx.num_kernels,), jnp.int32)
    for s, d in pattern:
        table = table.at[s].set(d)
    return table[ctx.my_id()]


def _exchange(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray,
              payload: jnp.ndarray | None, extra: jnp.ndarray | None = None):
    """One link traversal: ship ``header ++ [extra ++] payload`` along
    ``pattern`` as ONE fused packet (a single ``ppermute``), batched or
    not.  Header-only messages are already single packets.

    Returns ``(hdr, payload)`` — plus ``extra`` in the middle when an
    extra section was given.  Pure-local patterns (src == dst for every
    pair) short-circuit: no collective is issued, mirroring
    libGalapagos' internal routing for same-node kernels.  Non-32-bit
    payloads cannot bitcast onto the int32 wire and fall back to split
    collectives.
    """
    remote = [(s, d) for (s, d) in pattern if s != d]
    if not remote:
        return (hdr, extra, payload) if extra is not None else (hdr, payload)
    if payload is None and extra is None:
        return lax.ppermute(hdr, ctx.axes, pattern), None
    if payload is not None and not am.wire_dtype_ok(payload.dtype):
        hdr_r = lax.ppermute(hdr, ctx.axes, pattern)
        pay_r = lax.ppermute(payload, ctx.axes, pattern)
        if extra is None:
            return hdr_r, pay_r
        return hdr_r, lax.ppermute(extra, ctx.axes, pattern), pay_r
    n_extra = 0 if extra is None else extra.shape[-1]
    dtype = jnp.int32 if payload is None else payload.dtype
    pkt = am.pack_packet(hdr, payload, extra)
    pkt_r = lax.ppermute(pkt, ctx.axes, pattern)
    out = am.unpack_packet(pkt_r, dtype, n_extra)
    if payload is None and extra is not None:
        return out[0], out[1], None
    return out


def _mask_nonparticipants(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray):
    return jnp.where(_is_sender(ctx, pattern), hdr, jnp.zeros_like(hdr))


def _deliver_reply(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                   hdr_at_dst: am.Header, *, asynchronous: bool = False,
                   token=0, reply_via=None) -> PgasState:
    """Ship the auto-reply back along the reversed pattern and absorb it.

    For batched >MTU plans this is called once with the *final* segment's
    header — the only acked one — so a whole message costs one reply.

    Statically-async messages short-circuit here: previously an acked
    transport still shipped the (all-NOP, reply-suppressed) header back,
    wasting a collective XLA cannot DCE.  When ``reply_via`` (a reply
    mailbox, see :mod:`repro.actors`) is given, the reply is *deferred*
    instead of shipped: the mailbox records one owed credit for
    ``(pattern, token)`` and its flush returns all owed credits for a
    destination as ONE coalesced Short AM."""
    if not ctx.transport.acked or asynchronous:
        return state
    if reply_via is not None:
        reply_via.note(pattern, token)
        return state
    rep = gc.auto_reply(hdr_at_dst)
    rep_back, _ = _exchange(ctx, _reverse(pattern), rep, None)
    return gc.ingress_reply(state, am.decode(rep_back))


def _segments(nwords: int, limit: int):
    """Static segmentation plan: [(offset, words), ...]."""
    if nwords <= limit:
        return [(0, nwords)]
    out, off = [], 0
    while off < nwords:
        w = min(limit, nwords - off)
        out.append((off, w))
        off += w
    return out


def _resolve_nwords(payload, from_segment_addr, nwords, op_name: str) -> int:
    """Validate the two calling conventions and return the message size."""
    if payload is not None:
        return int(payload.size)
    if from_segment_addr is None or nwords is None:
        raise ValueError(
            f"{op_name}: pass either `payload` (FIFO variant: data from "
            "the kernel) or `from_segment_addr` AND `nwords` "
            "(memory-sourced variant: data read from the local segment)")
    return int(nwords)


def _seg_types(msg_class: int, nseg: int, *, asynchronous: bool, **flags):
    """Per-segment type words: every segment but the last is async, so
    an acked message triggers exactly one (coalesced) reply."""
    t_last = am.make_type(msg_class, asynchronous=asynchronous, **flags)
    t_tail = am.make_type(msg_class, asynchronous=True, **flags)
    if nseg == 1:
        return t_last
    return jnp.where(jnp.arange(nseg) == nseg - 1, t_last, t_tail)


# --------------------------------------------------------------------------
# Short AMs
# --------------------------------------------------------------------------

def put_short(ctx: ShoalContext, state: PgasState, pattern: Pattern, *,
              handler=hd.H_ADD, arg=1, token=0,
              asynchronous: bool = False, reply_via=None) -> PgasState:
    """Short AM: signal the destination (no payload).

    The handler runs on the destination's credit word ``token`` with
    ``arg``; the default (H_ADD, 1) is a counting semaphore.
    """
    h_s, a_s, t_s = (_lint.static_int(handler), _lint.static_int(arg),
                     _lint.static_int(token))
    grants = ((t_s, a_s),) if (h_s == hd.H_ADD and a_s is not None
                               and t_s is not None) else ()
    tag = _lint.emit(
        "put_short", pattern, token=t_s,
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        credit_grants=grants, handler=h_s, segment_words=ctx.segment_words)
    with _lint.scope(tag):
        t = am.make_type(am.SHORT, asynchronous=asynchronous)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        handler=handler, token=token, dst_addr=arg)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        hdr_r, _ = _exchange(ctx, pattern, hdr, None)
        h = am.decode(hdr_r)
        state = gc.ingress_short(ctx, state, h)
        return _deliver_reply(ctx, state, pattern, h,
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


# --------------------------------------------------------------------------
# Medium AMs (payload -> destination kernel)
# --------------------------------------------------------------------------

def put_medium(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
               pattern: Pattern, *, handler=hd.H_NOP, token=0,
               asynchronous: bool = False, from_segment_addr=None,
               nwords: int | None = None, reply_via=None):
    """Medium AM: point-to-point payload straight to the destination
    kernel (returned value).  ``from_segment_addr`` selects the
    memory-sourced variant (payload read from the local segment by the
    GAScore at that address, ``nwords`` long, i.e. the non-FIFO case);
    default is the FIFO variant with ``payload`` from the kernel.

    Returns ``(state, delivered)``; ``delivered`` is zeros on kernels
    that receive nothing this call.  >MTU payloads ship as one batched
    packet stack: a single collective plus (if acked) a single
    coalesced reply.
    """
    nwords = _resolve_nwords(payload, from_segment_addr, nwords, "put_medium")
    fifo = from_segment_addr is None
    tag = _lint.emit(
        "put_medium", pattern, token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        detail={"nwords": nwords})
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.MEDIUM, nseg, asynchronous=asynchronous,
                            fifo=fifo),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            handler=handler, token=token,
            src_addr=0 if fifo else from_segment_addr + offs, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload if fifo else None, W)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), nwords, 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state, delivered = gc.ingress_medium_batch(state, hdr_r, pay_r, W)
        state = _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                               asynchronous=asynchronous, token=token,
                               reply_via=reply_via)
        return state, delivered[:nwords]


# --------------------------------------------------------------------------
# Long AMs (payload -> destination shared memory)
# --------------------------------------------------------------------------

def put_long(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
             pattern: Pattern, dst_addr, *, handler=hd.H_WRITE, token=0,
             asynchronous: bool = False, from_segment_addr=None,
             nwords: int | None = None, reply_via=None) -> PgasState:
    """Long AM: one-sided put into the destination kernel's segment at
    ``dst_addr``, applied through ``handler`` (H_WRITE = plain put,
    H_ADD = remote accumulate, ...).  FIFO variant when ``payload`` is
    given; memory-sourced variant when ``from_segment_addr`` is.

    >MTU payloads ship as one ``(nseg, HDR+W)`` packet stack — a single
    collective — and are absorbed by a scanned GAScore ingress; an acked
    message earns ONE credit (the final segment carries the ack).
    """
    nwords = _resolve_nwords(payload, from_segment_addr, nwords, "put_long")
    fifo = from_segment_addr is None
    tag = _lint.emit(
        "put_long", pattern,
        writes=(_lint.Interval(_lint.static_int(dst_addr), nwords),),
        token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words)
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.LONG, nseg, asynchronous=asynchronous,
                            fifo=fifo),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            dst_addr=dst_addr + offs,
            src_addr=0 if fifo else from_segment_addr + offs,
            handler=handler, token=token, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload if fifo else None, W)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), nwords, 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state = gc.ingress_long_batch(ctx, state, hdr_r, pay_r, W)
        return _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


def _strides_may_overlap(stride, blk_words: int, nblocks: int) -> bool:
    """Static overlap detection for strided puts: True when consecutive
    blocks can alias (``|stride| < blk_words``).  A traced stride is
    conservatively treated as overlapping — the caller can override with
    the ``overlap`` kwarg when it knows better."""
    if nblocks <= 1:
        return False
    try:
        return abs(int(stride)) < blk_words
    except Exception:  # traced stride: cannot prove blocks disjoint
        return True


def put_long_strided(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray,
                     pattern: Pattern, dst_addr, stride, *,
                     blk_words: int, nblocks: int, handler=hd.H_WRITE,
                     token=0, asynchronous: bool = False,
                     overlap: bool | None = None, reply_via=None) -> PgasState:
    """Strided Long put: ``nblocks`` blocks of ``blk_words`` land at
    ``dst_addr + i*stride`` (THeGASNet's strided access, carried forward
    by the paper).  ``payload`` is the packed (nblocks*blk_words,)
    buffer — see :mod:`repro.kernels.am_pack` for the packing hot path.
    Block geometry is static; stride may be traced.

    >MTU messages segment at block granularity into one batched packet
    stack (single collective, one coalesced reply).

    Aliasing strides (``|stride| < blk_words``) are detected statically
    and ingress switches to the block-sequential scan that preserves
    last-writer-wins ordering; a traced stride is conservatively treated
    as aliasing.  ``overlap`` overrides the detection either way.
    """
    ordered = (_strides_may_overlap(stride, blk_words, nblocks)
               if overlap is None else bool(overlap))
    nwords = blk_words * nblocks
    base_s, stride_s = _lint.static_int(dst_addr), _lint.static_int(stride)
    if base_s is not None and stride_s is not None:
        w_ivs = tuple(_lint.Interval(base_s + i * stride_s, blk_words)
                      for i in range(nblocks))
    else:
        w_ivs = (_lint.Interval(None, nwords),)
    may_alias = _strides_may_overlap(stride, blk_words, nblocks)
    tag = _lint.emit(
        "put_long_strided", pattern, writes=w_ivs,
        token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        ordered_ingress=ordered, self_overlap=may_alias and not ordered,
        detail={"stride": stride_s, "blk_words": blk_words,
                "nblocks": nblocks})
    with _lint.scope(tag):
        # blocks per packet; >MTU plans segment at block granularity
        per = max(1, ctx.transport.max_packet_words // blk_words)
        nseg = -(-nblocks // per)
        nb = jnp.minimum(per,
                         nblocks - per * jnp.arange(nseg)).astype(jnp.int32)
        W = min(per, nblocks) * blk_words
        offs = jnp.arange(nseg, dtype=jnp.int32) * (per * blk_words)
        hdrs = am.encode_batch(
            nseg,
            type=_seg_types(am.LONG, nseg, asynchronous=asynchronous,
                            fifo=True, strided=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern),
            nwords=nb * blk_words,
            dst_addr=dst_addr + jnp.arange(nseg) * per * stride,
            handler=handler, token=token, stride=stride,
            blk_words=blk_words, nblocks=nb, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        buf = gc.egress_batch(ctx, state, hdrs, payload, W)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), nwords, 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdrs, buf)
        state = gc.ingress_strided_batch(ctx, state, hdr_r, pay_r, blk_words,
                                         min(per, nblocks), ordered)
        return _deliver_reply(ctx, state, pattern, am.decode(hdr_r[-1]),
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


def put_long_vectored(ctx: ShoalContext, state: PgasState,
                      blocks: list[jnp.ndarray], pattern: Pattern,
                      dst_addrs, *, handler=hd.H_WRITE, token=0,
                      asynchronous: bool = False, reply_via=None) -> PgasState:
    """Vectored Long put: ``blocks[i]`` lands at ``dst_addrs[i]``.  One
    AM on the wire: the destination address list rides inside the fused
    packet as an extra int32 section (``header ++ addrs ++ payload``),
    so the whole message is a single collective; the receiver scatters.
    Block sizes are static; addresses may be traced."""
    try:
        n_addrs = len(dst_addrs)
    except TypeError:
        n_addrs = int(jnp.shape(jnp.asarray(dst_addrs))[0])
    if n_addrs != len(blocks):
        # jnp indexing clamps, so a short address list would silently
        # alias trailing blocks onto the last address
        raise ValueError(
            f"put_long_vectored: {len(blocks)} blocks but {n_addrs} "
            "dst_addrs — one destination address per block")
    nwords = sum(int(b.size) for b in blocks)
    if nwords + len(blocks) > ctx.transport.max_packet_words:
        raise ValueError(
            f"put_long_vectored: {nwords} payload words + {len(blocks)} "
            f"in-packet addresses exceed the transport MTU "
            f"({ctx.transport.max_packet_words} words); vectored puts do "
            "not segment — split the block list across messages")
    sizes = [int(b.size) for b in blocks]
    ivs = _lint.intervals_for_blocks(list(dst_addrs), sizes)
    alias = next(((i, j) for i in range(len(ivs))
                  for j in range(i + 1, len(ivs))
                  if ivs[i].known and ivs[j].known
                  and ivs[i].overlaps(ivs[j])), None)
    if alias is not None and _lint.current_waiver() is None:
        i, j = alias
        raise VectoredAliasError(
            f"put_long_vectored: destination blocks {i} ({ivs[i]}) and "
            f"{j} ({ivs[j]}) overlap inside one packet, so the landed "
            "value depends on the receiver's scatter order (duplicate "
            "addresses are the degenerate case). Give each block a "
            "disjoint interval, or wrap the call in "
            "repro.analysis.waiver(reason) if the overlap is deliberate.")
    tag = _lint.emit(
        "put_long_vectored", pattern, writes=ivs,
        token=_lint.static_int(token),
        acked=ctx.transport.acked and not asynchronous,
        asynchronous=asynchronous, deferred_reply=reply_via is not None,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        self_overlap=alias is not None,
        detail={} if alias is None else
        {"alias": f"blocks {alias[0]} and {alias[1]} overlap"})
    with _lint.scope(tag):
        payload = jnp.concatenate([b.reshape(-1) for b in blocks])
        t = am.make_type(am.LONG, asynchronous=asynchronous, fifo=True,
                         vectored=True)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=nwords, handler=handler, token=token,
                        nblocks=len(blocks))
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        buf = gc.egress(ctx, state, am.decode(hdr), payload, nwords)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), nwords, 0))
        addrs = jnp.asarray(dst_addrs, jnp.int32)
        hdr_r, addrs_r, pay_r = _exchange(ctx, pattern, hdr, buf, extra=addrs)
        h = am.decode(hdr_r)
        off = 0
        for i, b in enumerate(blocks):
            w = int(b.size)
            sub_hdr = am.Header(
                type=h.type, src=h.src, dst=h.dst,
                nwords=jnp.asarray(w, jnp.int32),
                dst_addr=addrs_r[i], src_addr=h.src_addr, handler=h.handler,
                token=h.token, stride=h.stride, blk_words=h.blk_words,
                nblocks=h.nblocks, seq=h.seq)
            state = gc.ingress_long(ctx, state, sub_hdr,
                                    lax.dynamic_slice(pay_r, (off,), (w,)), w)
            off += w
        return _deliver_reply(ctx, state, pattern, h,
                              asynchronous=asynchronous, token=token,
                              reply_via=reply_via)


# --------------------------------------------------------------------------
# Gets (one round trip: request header out, data back)
# --------------------------------------------------------------------------

def get_medium(ctx: ShoalContext, state: PgasState, pattern: Pattern,
               src_addr, nwords: int, *, token=0):
    """Medium get: fetch ``nwords`` at ``src_addr`` in the *destination*
    kernel's segment, delivered to the requesting kernel.  Returns
    ``(state, data)``.  The data return doubles as the reply (credits
    bump ONCE per message, on the final segment).  >MTU gets batch all
    request headers into one collective and the whole response into a
    second: 2 link traversals regardless of segment count."""
    tag = _lint.emit(
        "get_medium", pattern,
        reads=(_lint.Interval(_lint.static_int(src_addr), int(nwords)),),
        token=_lint.static_int(token), acked=True,
        segment_words=ctx.segment_words)
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg, type=am.make_type(am.MEDIUM, get=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            src_addr=src_addr + offs, token=token, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        hdr_r, _ = _exchange(ctx, pattern, hdrs, None)
        state, resp_rows, data_rows = gc.serve_get_batch(ctx, state, hdr_r, W)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_rows,
                                        data_rows)
        state = gc.ingress_reply(state, am.decode(back_hdr[-1]))
        state, data = gc.ingress_medium_batch(state, back_hdr, back_data, W)
        return state, data[:nwords]


def get_long(ctx: ShoalContext, state: PgasState, pattern: Pattern,
             src_addr, nwords: int, dst_addr, *, handler=hd.H_WRITE,
             token=0) -> PgasState:
    """Long get: fetch remote segment words into the *local* segment at
    ``dst_addr`` (one-sided read).  Same batched 2-traversal wire plan
    as :func:`get_medium`; one credit per message."""
    tag = _lint.emit(
        "get_long", pattern,
        reads=(_lint.Interval(_lint.static_int(src_addr), int(nwords)),),
        token=_lint.static_int(token), acked=True,
        handler=_lint.static_int(handler), segment_words=ctx.segment_words,
        detail={"local_dst_addr": _lint.static_int(dst_addr)})
    with _lint.scope(tag):
        segs = _segments(nwords, ctx.transport.max_packet_words)
        nseg, W = len(segs), segs[0][1]
        offs = jnp.asarray([o for o, _ in segs], jnp.int32)
        ws = jnp.asarray([w for _, w in segs], jnp.int32)
        hdrs = am.encode_batch(
            nseg, type=am.make_type(am.LONG, get=True),
            src=ctx.my_id(), dst=_dst_of(ctx, pattern), nwords=ws,
            src_addr=src_addr + offs, dst_addr=dst_addr + offs,
            token=token, handler=handler, seq=offs)
        hdrs = _mask_nonparticipants(ctx, pattern, hdrs)
        hdr_r, _ = _exchange(ctx, pattern, hdrs, None)
        state, resp_rows, data_rows = gc.serve_get_batch(ctx, state, hdr_r, W)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_rows,
                                        data_rows)
        state = gc.ingress_reply(state, am.decode(back_hdr[-1]))
        # land in local segment through the handler (class LONG on the wire)
        is_rep = (back_hdr[:, 0] & am.FLAG_REPLY) != 0
        land_rows = back_hdr.at[:, 0].set(
            jnp.where(is_rep, am.LONG, am.NOP).astype(jnp.int32))
        return gc.ingress_long_batch(ctx, state, land_rows, back_data, W)


# --------------------------------------------------------------------------
# synchronization
# --------------------------------------------------------------------------

def barrier(ctx: ShoalContext, state: PgasState) -> PgasState:
    """Global barrier over all kernels (paper Sec. III: "barriers for
    synchronization").  A psum of a unit scalar is the dataflow barrier:
    no kernel's successor ops can be scheduled before every kernel's
    contribution arrives.  The barrier epoch counts completions."""
    tag = _lint.emit("barrier", [])
    with _lint.scope(tag):
        arrived = lax.psum(jnp.ones((), jnp.int32), ctx.axes)
        epoch = state.barrier_epoch + (arrived // arrived)  # data-dependent
        return gc.dataclasses_replace(state, barrier_epoch=epoch)


def wait_replies(ctx: ShoalContext, state: PgasState, token, n) -> PgasState:
    """Wait for ``n`` replies on ``token`` then consume them.

    Replies coalesce across >MTU segmentation, so ``n`` counts
    *messages*, not packets.  In SPMD dataflow, arrival is guaranteed by
    data dependence, so this is bookkeeping: it drains ``n`` credits and
    raises a sticky error bit if fewer than ``n`` were present — the
    observable equivalent of a hang in the threaded original (tests
    assert on it).  On the host, :func:`repro.core.state.raise_on_error`
    converts the bit into a named :class:`~repro.core.state.
    WaitUnderflowError` carrying the offending token id(s).
    """
    tag = _lint.emit("wait_replies", [], token=_lint.static_int(token),
                     wait_n=_lint.static_int(n))
    with _lint.scope(tag):
        token = jnp.clip(jnp.asarray(token, jnp.int32), 0, hd.NUM_TOKENS - 1)
        have = state.credits[token]
        err = jnp.where(have < n, ERR_WAIT_UNDERFLOW, 0).astype(jnp.int32)
        credits = hd.drain_credits(state.credits, token, n)
        return gc.dataclasses_replace(state, credits=credits,
                                      error=state.error | err)
