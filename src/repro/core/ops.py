"""The Shoal communication API (paper Sec. III-A).

Every function here is the SPMD-collectivized form of a Shoal AM call:
all kernels execute the same line; ``pattern`` is a static list of
``(src_kernel, dst_kernel)`` pairs naming who actually communicates this
call, and kernels outside the pattern contribute NOP headers (no action,
no reply).  This is the dataflow adaptation of one-sided messaging: a
put is ONE link traversal (plus an optional auto-reply), with no
rendezvous — contrast :mod:`repro.core.humboldt`, the two-sided baseline,
which costs four.

All ops must run inside ``shard_map`` over ``ctx.axes`` (use
``ctx.spmd``).  They thread :class:`PgasState` functionally.

Message-size segmentation: AMs whose payload exceeds the transport's
``max_packet_words`` are transparently split into sequence-numbered
packets.  The paper hits this limit (9000-byte jumbo frames) in the
Jacobi application and leaves segmentation as future work (footnote 2);
we implement it.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import am
from repro.core import gascore as gc
from repro.core import handlers as hd
from repro.core.state import ERR_WAIT_UNDERFLOW, PgasState, ShoalContext

Pattern = list[tuple[int, int]]


# --------------------------------------------------------------------------
# pattern plumbing
# --------------------------------------------------------------------------

def _reverse(pattern: Pattern) -> Pattern:
    return [(d, s) for (s, d) in pattern]


def _is_sender(ctx: ShoalContext, pattern: Pattern):
    me = ctx.my_id()
    srcs = jnp.asarray([s for s, _ in pattern] or [-1], jnp.int32)
    return jnp.any(me == srcs)


def _dst_of(ctx: ShoalContext, pattern: Pattern):
    """Per-kernel destination (or -1): a trace-time table lookup."""
    table = -jnp.ones((ctx.num_kernels,), jnp.int32)
    for s, d in pattern:
        table = table.at[s].set(d)
    return table[ctx.my_id()]


def _exchange(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray,
              payload: jnp.ndarray | None):
    """One link traversal: ship (header, payload) along ``pattern``.

    Pure-local patterns (src == dst for every pair) short-circuit: no
    collective is issued, mirroring libGalapagos' internal routing for
    same-node kernels.
    """
    remote = [(s, d) for (s, d) in pattern if s != d]
    if not remote:
        return hdr, payload
    hdr_r = lax.ppermute(hdr, ctx.axes, pattern)
    pay_r = None if payload is None else lax.ppermute(payload, ctx.axes, pattern)
    return hdr_r, pay_r


def _mask_nonparticipants(ctx: ShoalContext, pattern: Pattern, hdr: jnp.ndarray):
    return jnp.where(_is_sender(ctx, pattern), hdr, jnp.zeros_like(hdr))


def _deliver_reply(ctx: ShoalContext, state: PgasState, pattern: Pattern,
                   hdr_at_dst: am.Header) -> PgasState:
    """Ship the auto-reply back along the reversed pattern and absorb it."""
    if not ctx.transport.acked:
        return state
    rep = gc.auto_reply(hdr_at_dst)
    rep_back, _ = _exchange(ctx, _reverse(pattern), rep, None)
    return gc.ingress_reply(state, am.decode(rep_back))


def _segments(nwords: int, limit: int):
    """Static segmentation plan: [(offset, words), ...]."""
    if nwords <= limit:
        return [(0, nwords)]
    out, off = [], 0
    while off < nwords:
        w = min(limit, nwords - off)
        out.append((off, w))
        off += w
    return out


# --------------------------------------------------------------------------
# Short AMs
# --------------------------------------------------------------------------

def put_short(ctx: ShoalContext, state: PgasState, pattern: Pattern, *,
              handler=hd.H_ADD, arg=1, token=0,
              asynchronous: bool = False) -> PgasState:
    """Short AM: signal the destination (no payload).

    The handler runs on the destination's credit word ``token`` with
    ``arg``; the default (H_ADD, 1) is a counting semaphore.
    """
    t = am.make_type(am.SHORT, asynchronous=asynchronous)
    hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                    handler=handler, token=token, dst_addr=arg)
    hdr = _mask_nonparticipants(ctx, pattern, hdr)
    hdr_r, _ = _exchange(ctx, pattern, hdr, None)
    h = am.decode(hdr_r)
    state = gc.ingress_short(ctx, state, h)
    return _deliver_reply(ctx, state, pattern, h)


# --------------------------------------------------------------------------
# Medium AMs (payload -> destination kernel)
# --------------------------------------------------------------------------

def put_medium(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
               pattern: Pattern, *, handler=hd.H_NOP, token=0,
               asynchronous: bool = False, from_segment_addr=None,
               nwords: int | None = None):
    """Medium AM: point-to-point payload straight to the destination
    kernel (returned value).  ``from_segment_addr`` selects the
    memory-sourced variant (payload read from the local segment by the
    GAScore at that address, ``nwords`` long, i.e. the non-FIFO case);
    default is the FIFO variant with ``payload`` from the kernel.

    Returns ``(state, delivered)``; ``delivered`` is zeros on kernels
    that receive nothing this call.
    """
    if payload is not None:
        nwords = int(payload.size)
    assert nwords is not None
    limit = ctx.transport.max_packet_words
    fifo = from_segment_addr is None
    out_parts = []
    for off, w in _segments(nwords, limit):
        t = am.make_type(am.MEDIUM, asynchronous=asynchronous, fifo=fifo)
        src_addr = 0 if fifo else from_segment_addr + off
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=w, handler=handler, token=token,
                        src_addr=src_addr, seq=off)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        chunk = payload.reshape(-1)[off:off + w] if fifo else None
        buf = gc.egress(ctx, state, am.decode(hdr), chunk, w)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), w, 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdr, buf)
        h = am.decode(hdr_r)
        state, part = gc.ingress_medium(state, h, pay_r, w)
        state = _deliver_reply(ctx, state, pattern, h)
        out_parts.append(part)
    delivered = jnp.concatenate(out_parts) if len(out_parts) > 1 else out_parts[0]
    return state, delivered


# --------------------------------------------------------------------------
# Long AMs (payload -> destination shared memory)
# --------------------------------------------------------------------------

def put_long(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray | None,
             pattern: Pattern, dst_addr, *, handler=hd.H_WRITE, token=0,
             asynchronous: bool = False, from_segment_addr=None,
             nwords: int | None = None) -> PgasState:
    """Long AM: one-sided put into the destination kernel's segment at
    ``dst_addr``, applied through ``handler`` (H_WRITE = plain put,
    H_ADD = remote accumulate, ...).  FIFO variant when ``payload`` is
    given; memory-sourced variant when ``from_segment_addr`` is.
    """
    if payload is not None:
        nwords = int(payload.size)
    assert nwords is not None
    limit = ctx.transport.max_packet_words
    for off, w in _segments(nwords, limit):
        fifo = from_segment_addr is None
        t = am.make_type(am.LONG, asynchronous=asynchronous, fifo=fifo)
        src_addr = 0 if fifo else from_segment_addr + off
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=w, dst_addr=dst_addr + off, src_addr=src_addr,
                        handler=handler, token=token, seq=off)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        chunk = payload.reshape(-1)[off:off + w] if fifo else None
        buf = gc.egress(ctx, state, am.decode(hdr), chunk, w)
        state = gc.dataclasses_replace(
            state, tx_words=state.tx_words +
            jnp.where(_is_sender(ctx, pattern), w, 0))
        hdr_r, pay_r = _exchange(ctx, pattern, hdr, buf)
        h = am.decode(hdr_r)
        state = gc.ingress_long(ctx, state, h, pay_r, w)
        state = _deliver_reply(ctx, state, pattern, h)
    return state


def put_long_strided(ctx: ShoalContext, state: PgasState, payload: jnp.ndarray,
                     pattern: Pattern, dst_addr, stride, *,
                     blk_words: int, nblocks: int, handler=hd.H_WRITE,
                     token=0, asynchronous: bool = False) -> PgasState:
    """Strided Long put: ``nblocks`` blocks of ``blk_words`` land at
    ``dst_addr + i*stride`` (THeGASNet's strided access, carried forward
    by the paper).  ``payload`` is the packed (nblocks*blk_words,)
    buffer — see :mod:`repro.kernels.am_pack` for the packing hot path.
    Block geometry is static; stride may be traced.
    """
    nwords = blk_words * nblocks
    if nwords > ctx.transport.max_packet_words:
        # segment at block granularity
        per = max(1, ctx.transport.max_packet_words // blk_words)
        for b0 in range(0, nblocks, per):
            nb = min(per, nblocks - b0)
            sub = payload[b0 * blk_words:(b0 + nb) * blk_words]
            state = put_long_strided(
                ctx, state, sub, pattern, dst_addr + b0 * stride, stride,
                blk_words=blk_words, nblocks=nb, handler=handler,
                token=token, asynchronous=asynchronous)
        return state
    t = am.make_type(am.LONG, asynchronous=asynchronous, fifo=True, strided=True)
    hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                    nwords=nwords, dst_addr=dst_addr, handler=handler,
                    token=token, stride=stride, blk_words=blk_words,
                    nblocks=nblocks)
    hdr = _mask_nonparticipants(ctx, pattern, hdr)
    buf = gc.egress(ctx, state, am.decode(hdr), payload, nwords)
    state = gc.dataclasses_replace(
        state, tx_words=state.tx_words +
        jnp.where(_is_sender(ctx, pattern), nwords, 0))
    hdr_r, pay_r = _exchange(ctx, pattern, hdr, buf)
    h = am.decode(hdr_r)
    state = gc.ingress_strided(ctx, state, h, pay_r, blk_words, nblocks)
    return _deliver_reply(ctx, state, pattern, h)


def put_long_vectored(ctx: ShoalContext, state: PgasState,
                      blocks: list[jnp.ndarray], pattern: Pattern,
                      dst_addrs, *, handler=hd.H_WRITE, token=0,
                      asynchronous: bool = False) -> PgasState:
    """Vectored Long put: ``blocks[i]`` lands at ``dst_addrs[i]``.  One
    AM on the wire (blocks concatenated); the receiver scatters.  Block
    sizes are static; addresses may be traced."""
    nwords = sum(int(b.size) for b in blocks)
    payload = jnp.concatenate([b.reshape(-1) for b in blocks])
    t = am.make_type(am.LONG, asynchronous=asynchronous, fifo=True, vectored=True)
    hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                    nwords=nwords, handler=handler, token=token,
                    nblocks=len(blocks))
    hdr = _mask_nonparticipants(ctx, pattern, hdr)
    buf = gc.egress(ctx, state, am.decode(hdr), payload, nwords)
    hdr_r, pay_r = _exchange(ctx, pattern, hdr, buf)
    h = am.decode(hdr_r)
    addrs_r = lax.ppermute(jnp.asarray(dst_addrs, jnp.int32), ctx.axes, pattern) \
        if any(s != d for s, d in pattern) else jnp.asarray(dst_addrs, jnp.int32)
    off = 0
    for i, b in enumerate(blocks):
        w = int(b.size)
        sub_hdr = am.Header(
            type=h.type, src=h.src, dst=h.dst, nwords=jnp.asarray(w, jnp.int32),
            dst_addr=addrs_r[i], src_addr=h.src_addr, handler=h.handler,
            token=h.token, stride=h.stride, blk_words=h.blk_words,
            nblocks=h.nblocks, seq=h.seq)
        state = gc.ingress_long(ctx, state, sub_hdr,
                                lax.dynamic_slice(pay_r, (off,), (w,)), w)
        off += w
    return _deliver_reply(ctx, state, pattern, h)


# --------------------------------------------------------------------------
# Gets (one round trip: request header out, data back)
# --------------------------------------------------------------------------

def get_medium(ctx: ShoalContext, state: PgasState, pattern: Pattern,
               src_addr, nwords: int, *, token=0):
    """Medium get: fetch ``nwords`` at ``src_addr`` in the *destination*
    kernel's segment, delivered to the requesting kernel.  Returns
    ``(state, data)``.  The data return doubles as the reply (credits
    bump on receipt)."""
    limit = ctx.transport.max_packet_words
    parts = []
    for off, w in _segments(nwords, limit):
        t = am.make_type(am.MEDIUM, get=True)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=w, src_addr=src_addr + off, token=token)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        hdr_r, _ = _exchange(ctx, pattern, hdr, None)
        state, resp_hdr, data = gc.serve_get(ctx, state, am.decode(hdr_r), w)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_hdr, data)
        hb = am.decode(back_hdr)
        state = gc.ingress_reply(state, hb)
        state, part = gc.ingress_medium(state, hb, back_data, w)
        parts.append(part)
    data = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return state, data


def get_long(ctx: ShoalContext, state: PgasState, pattern: Pattern,
             src_addr, nwords: int, dst_addr, *, handler=hd.H_WRITE,
             token=0) -> PgasState:
    """Long get: fetch remote segment words into the *local* segment at
    ``dst_addr`` (one-sided read)."""
    limit = ctx.transport.max_packet_words
    for off, w in _segments(nwords, limit):
        t = am.make_type(am.LONG, get=True)
        hdr = am.encode(type=t, src=ctx.my_id(), dst=_dst_of(ctx, pattern),
                        nwords=w, src_addr=src_addr + off,
                        dst_addr=dst_addr + off, token=token, handler=handler)
        hdr = _mask_nonparticipants(ctx, pattern, hdr)
        hdr_r, _ = _exchange(ctx, pattern, hdr, None)
        state, resp_hdr, data = gc.serve_get(ctx, state, am.decode(hdr_r), w)
        back_hdr, back_data = _exchange(ctx, _reverse(pattern), resp_hdr, data)
        hb = am.decode(back_hdr)
        state = gc.ingress_reply(state, hb)
        # land in local segment through the handler (class LONG on the wire)
        land = am.Header(
            type=jnp.where(hb.flag(am.FLAG_REPLY), jnp.asarray(am.LONG), jnp.asarray(am.NOP)).astype(jnp.int32),
            src=hb.src, dst=hb.dst, nwords=hb.nwords, dst_addr=hb.dst_addr,
            src_addr=hb.src_addr, handler=hb.handler, token=hb.token,
            stride=hb.stride, blk_words=hb.blk_words, nblocks=hb.nblocks,
            seq=hb.seq)
        state = gc.ingress_long(ctx, state, land, back_data, w)
    return state


# --------------------------------------------------------------------------
# synchronization
# --------------------------------------------------------------------------

def barrier(ctx: ShoalContext, state: PgasState) -> PgasState:
    """Global barrier over all kernels (paper Sec. III: "barriers for
    synchronization").  A psum of a unit scalar is the dataflow barrier:
    no kernel's successor ops can be scheduled before every kernel's
    contribution arrives.  The barrier epoch counts completions."""
    arrived = lax.psum(jnp.ones((), jnp.int32), ctx.axes)
    epoch = state.barrier_epoch + (arrived // arrived)  # +1, data-dependent
    return gc.dataclasses_replace(state, barrier_epoch=epoch)


def wait_replies(ctx: ShoalContext, state: PgasState, token, n) -> PgasState:
    """Wait for ``n`` replies on ``token`` then consume them.

    In SPMD dataflow, arrival is guaranteed by data dependence, so this
    is bookkeeping: it drains ``n`` credits and raises a sticky error
    bit if fewer than ``n`` were present — the observable equivalent of
    a hang in the threaded original (tests assert on it).
    """
    token = jnp.clip(jnp.asarray(token, jnp.int32), 0, hd.NUM_TOKENS - 1)
    have = state.credits[token]
    err = jnp.where(have < n, ERR_WAIT_UNDERFLOW, 0).astype(jnp.int32)
    credits = hd.drain_credits(state.credits, token, n)
    return gc.dataclasses_replace(state, credits=credits,
                                  error=state.error | err)
