"""The partitioned global address space (paper Sec. II-A3).

A ``GlobalAddressSpace`` names a global word array of
``num_kernels * segment_words`` words; kernel *k* owns words
``[k*segment_words, (k+1)*segment_words)``.  Locality is explicit: a
global address resolves to (owner kernel, local offset), and only
accesses to non-owned partitions become AMs — "this locality information
is known to the programmer" (Sec. II-A3).

Host-side helpers move data between a NumPy/global view and the
per-device segments (sharded ``jax.Array``), which is how applications
(e.g. Jacobi) load initial conditions and read results back.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.core.state import PgasState, ShoalContext


@dataclasses.dataclass(frozen=True)
class GlobalAddressSpace:
    ctx: ShoalContext
    dtype: jnp.dtype = jnp.float32

    @property
    def segment_words(self) -> int:
        return self.ctx.segment_words

    @property
    def total_words(self) -> int:
        return self.ctx.num_kernels * self.ctx.segment_words

    # -- addressing -------------------------------------------------------

    def owner_of(self, gaddr: int) -> int:
        return gaddr // self.segment_words

    def local_offset(self, gaddr: int) -> int:
        return gaddr % self.segment_words

    def global_addr(self, kernel: int, offset: int) -> int:
        if not 0 <= kernel < self.ctx.num_kernels:
            raise ValueError(
                f"global_addr: kernel {kernel} out of range "
                f"(num_kernels={self.ctx.num_kernels})")
        if not 0 <= offset < self.segment_words:
            # an out-of-range offset would silently alias into another
            # kernel's partition of the flat global word array
            would_own = (kernel * self.segment_words + offset) // self.segment_words
            raise ValueError(
                f"global_addr: offset {offset} outside the "
                f"{self.segment_words}-word segment owned by kernel "
                f"{kernel}; the aliased address would land in kernel "
                f"{would_own}'s partition at local offset "
                f"{offset % self.segment_words}")
        return kernel * self.segment_words + offset

    def check_local_range(self, kernel: int, offset: int, nwords: int) -> int:
        """Validate that ``[offset, offset + nwords)`` stays inside
        ``kernel``'s segment; returns ``offset``.  Used by callers that
        hand *local* destination addresses to the AM ops (where aliasing
        past the segment end is clipped by the GAScore, not wrapped)."""
        self.global_addr(kernel, offset)
        if nwords < 0 or offset + nwords > self.segment_words:
            raise ValueError(
                f"range [{offset}, {offset + nwords}) overruns kernel "
                f"{kernel}'s {self.segment_words}-word segment")
        return offset

    def vectored_addrs(self, kernel: int, base: int, block_words,
                       *, stride: int | None = None) -> list[int]:
        """Per-block local addresses for a vectored put into ``kernel``.

        ``block_words`` is the static per-block word count list; blocks
        land back-to-back from ``base`` unless ``stride`` pins a fixed
        distance between block starts (the per-layer stride of a KV
        segment layout).  Every block is validated against the segment
        bounds, so a bad layout fails at trace time with the owner in
        the message instead of silently clipping at ingress.
        """
        addrs, off = [], base
        for i, w in enumerate(block_words):
            a = base + i * stride if stride is not None else off
            self.check_local_range(kernel, a, int(w))
            addrs.append(a)
            off = a + int(w)
        return addrs

    # -- host <-> device views ---------------------------------------------

    def _sharding(self):
        return NamedSharding(self.ctx.mesh, P(self.ctx.axes))

    def make_global_state(self, init: np.ndarray | None = None):
        """Build the sharded PgasState for all kernels.

        Returns a PgasState whose leaves are global arrays with leading
        dim = num_kernels, sharded one-kernel-per-device; inside
        ``ctx.spmd`` each kernel sees its own (segment_words,) slice.
        """
        n = self.ctx.num_kernels
        proto = PgasState.make(self.segment_words, self.dtype)

        def globalize(leaf):
            arr = np.broadcast_to(np.asarray(leaf)[None], (n,) + leaf.shape).copy()
            return arr

        leaves = jax.tree.map(globalize, proto)
        if init is not None:
            if init.size != self.total_words:
                raise ValueError(
                    f"init has {init.size} words, address space has {self.total_words}")
            import dataclasses as _dc
            leaves = _dc.replace(
                leaves,
                segment=init.reshape(n, self.segment_words).astype(self.dtype))
        shd = self._sharding()

        def put(leaf):
            spec = P(self.ctx.axes) if leaf.ndim >= 1 else P(self.ctx.axes)
            # every leaf gained a leading kernel dim
            return jax.device_put(leaf, NamedSharding(self.ctx.mesh, P(self.ctx.axes)))

        return jax.tree.map(put, leaves)

    def read_global(self, state: PgasState) -> np.ndarray:
        """Gather the whole address space back to the host (all segments,
        kernel order)."""
        return np.asarray(jax.device_get(state.segment)).reshape(-1)

    def spmd(self, fn, **kw):
        """shard_map wrapper: ``fn(state) -> state`` written per-kernel;
        the global view gives every PgasState leaf a leading kernel dim
        split over the kernel axes, removed inside."""
        spec = P(self.ctx.axes)

        def inner(state):
            state = jax.tree.map(lambda x: x[0], state)  # drop kernel dim
            out = fn(state)
            return jax.tree.map(lambda x: x[None], out)

        return shard_map(inner, mesh=self.ctx.mesh, in_specs=spec,
                             out_specs=spec, **kw)
