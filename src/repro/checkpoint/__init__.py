from repro.checkpoint.checkpoint import CheckpointManager, ChecksumError

__all__ = ["CheckpointManager", "ChecksumError"]
