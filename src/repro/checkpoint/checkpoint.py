"""Sharded, atomic, async, topology-independent checkpointing.

Checkpoints are *globally addressed* — each leaf is stored as the full
global array plus its tree path (the same locality philosophy as the
PGAS segments the paper builds: names are global, placement is a
property of the restore-time mesh).  Restoring onto a different mesh /
device count therefore reshards transparently (**elastic scaling**), and
restore is bitwise (tests assert loss-curve continuation).

Layout per step::

    <dir>/step_000042/
        manifest.json        # tree structure, shapes/dtypes, sha256s, extras
        leaf_00000.npy ...   # one file per leaf

Writes go to ``step_X.tmp`` and are atomically renamed, so a crash
mid-save never corrupts the latest-checkpoint pointer.  ``save_async``
snapshots device arrays to host immediately (so training can proceed)
and writes on a background thread.  At true multi-host scale each host
would write only the shards it owns and the manifest records the
global shape — the format already stores global metadata per leaf.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


class ChecksumError(IOError):
    """A restored leaf file failed its manifest sha256 (bit rot, torn
    write, or a transport fault on shared storage).  Carries enough to
    act on: which file, what the manifest promised, what the bytes
    hashed to."""

    def __init__(self, path: str, file: str, expected: str, actual: str):
        self.path = path
        self.file = file
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"checksum mismatch for leaf {path!r} ({file}): manifest "
            f"sha256 {expected}, file hashed {actual} — the checkpoint "
            "file is corrupt (re-read once already; restore from an "
            "earlier step or re-replicate the file)")


def _read_verified(d: str, entry: dict, name: str) -> np.ndarray:
    """Load one leaf file, verifying its manifest sha256.

    A mismatch is re-read ONCE before failing — a concurrent replicator
    or page-cache race can yield one torn read on shared storage, but a
    second identical mismatch means the bytes really are wrong, and we
    raise :class:`ChecksumError` with both digests.
    """
    path = os.path.join(d, entry["file"])
    actual = None
    for _attempt in range(2):
        with open(path, "rb") as f:
            actual = hashlib.sha256(f.read()).hexdigest()
        if actual == entry["sha256"]:
            return np.load(path)
    raise ChecksumError(name, entry["file"], entry["sha256"], actual)


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extras: dict | None = None):
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host, extras or {})

    def save_async(self, step: int, tree, extras: dict | None = None):
        """Snapshot to host now; write in the background."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        t = threading.Thread(target=self._write, args=(step, host, extras or {}))
        t.start()
        self._pending = t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, extras: dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        names, leaves, _ = _tree_paths(host_tree)
        manifest = {"step": step, "extras": extras, "leaves": []}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            with open(os.path.join(tmp, fname), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"].append({
                "path": name, "file": fname, "shape": list(leaf.shape),
                "dtype": str(leaf.dtype), "sha256": digest,
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, shardings=None,
                verify: bool = False):
        """Restore into the structure of ``like``.  ``shardings``: optional
        matching tree of NamedSharding — restoring onto a different mesh
        reshards here (elastic restart).  Returns (tree, extras)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names, leaves, treedef = _tree_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out = []
        shard_flat = None
        if shardings is not None:
            _, shard_flat, _ = _tree_paths(shardings)
        for i, name in enumerate(names):
            entry = by_path[name]
            if verify:
                arr = _read_verified(d, entry, name)
            else:
                arr = np.load(os.path.join(d, entry["file"]))
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i])
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        return treedef.unflatten(out), manifest["extras"]
