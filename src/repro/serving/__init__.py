from repro.serving.engine import Request, ServeEngine
from repro.serving.frontend import (DONE, QUEUED, REJECTED, RUNNING, Job,
                                    ServeFrontend)
from repro.serving.kv_space import MIGRATE_TOKEN, KvSegmentSpace

__all__ = [
    "ServeEngine", "Request",
    "ServeFrontend", "Job", "QUEUED", "RUNNING", "DONE", "REJECTED",
    "KvSegmentSpace", "MIGRATE_TOKEN",
]


def __getattr__(name):
    # DisaggServeTier pulls in mesh/shard_map machinery; import lazily so
    # `from repro.serving import ServeEngine` stays light.
    if name in ("DisaggServeTier", "PrefillWorker"):
        from repro.serving import disagg

        return getattr(disagg, name)
    raise AttributeError(name)
