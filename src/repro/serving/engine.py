"""Serving engine: batched prefill + decode with slot management.

The decode step is a single compiled program over a fixed batch of
*lanes*; requests are multiplexed onto free lanes (continuous-batching
style).  Each lane tracks its own absolute position, so mixed-progress
lanes decode together in one program — ring caches and the position-
masked attention make this correct (slots whose ``pos`` is -1 never
attend).

``serve_step`` (= one ``decode_step`` over the full lane batch) is what
the ``decode_*`` / ``long_*`` dry-run shapes lower.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.events import EventMailbox, SlotEvent
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, lanes: int, slots: int,
                 greedy: bool = True, temperature: float = 1.0, seed: int = 0,
                 event_sink=None, event_watermark: int = 64):
        self.model = model
        self.params = params
        self.lanes = lanes
        self.slots = slots
        self.greedy = greedy
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        # slot accounting goes through a mailbox: acquire/release events
        # batch up and reach event_sink once per decode step (phase
        # boundary), not once per lane transition
        self.events = EventMailbox(watermark=event_watermark,
                                   sink=event_sink)

        self.cache = model.make_cache(lanes, slots)
        self.pos = np.zeros((lanes,), np.int32)
        self.last_tok = np.zeros((lanes,), np.int32)
        self.active: list[Request | None] = [None] * lanes
        self._decode = jax.jit(model.decode_step)
        # single-lane prefill (prompts have ragged lengths; each fills its
        # own lane's cache slice)
        self._prefill_one = jax.jit(self._prefill_lane)

    # -- lane-granular prefill ------------------------------------------------

    def _prefill_lane(self, params, cache, tokens, lane):
        """Run a (1, S) prompt and write its cache into lane ``lane``."""
        lane_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, lane, 1, axis=1)
            if c.ndim >= 2 else c, cache)
        logits, lane_cache = self.model.prefill(params, {"tokens": tokens},
                                                lane_cache)
        cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), lane, axis=1)
            if full.ndim >= 2 else one, cache, lane_cache)
        return logits, cache

    # -- scheduling -------------------------------------------------------------

    def _reset_lane(self, lane: int):
        """Clear a lane's cache before reuse: position slots to -1 (so the
        masked attention ignores them), recurrent states to their inits."""

        def reset(path, c):
            if c.ndim < 2:
                return c
            name = str(getattr(path[-1], "key", path[-1]))
            lane_shape = c.shape[:1] + (1,) + c.shape[2:]
            if name == "pos":
                fresh = -jnp.ones(lane_shape, c.dtype)
            elif name == "m":
                fresh = jnp.full(lane_shape, -30.0, c.dtype)
            else:
                fresh = jnp.zeros(lane_shape, c.dtype)
            return jax.lax.dynamic_update_slice_in_dim(c, fresh, lane, axis=1)

        self.cache = jax.tree_util.tree_map_with_path(reset, self.cache)

    def submit(self, req: Request) -> bool:
        """Place a request on a free lane (prefill now).  False if full."""
        for lane, cur in enumerate(self.active):
            if cur is None:
                self._reset_lane(lane)
                self.active[lane] = req
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, self.cache = self._prefill_one(
                    self.params, self.cache, toks, lane)
                tok = self._sample(np.asarray(logits)[0])
                req.out.append(int(tok))
                self.pos[lane] = len(req.prompt)
                self.last_tok[lane] = tok
                self.events.send(SlotEvent("acquire", lane, req.rid))
                return True
        return False

    def _sample(self, logits: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self):
        """One decode step for all active lanes."""
        if not any(r is not None and not r.done for r in self.active):
            return
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        logits = np.asarray(logits, np.float32)
        for lane, req in enumerate(self.active):
            if req is None or req.done:
                continue
            tok = self._sample(logits[lane])
            req.out.append(tok)
            self.pos[lane] += 1
            self.last_tok[lane] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[lane] = None
                self.events.send(SlotEvent("release", lane, req.rid))
        # phase boundary: this step's slot events go out as one batch
        self.events.flush()

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a request list to completion (simple FCFS scheduler)."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        self.events.flush()
        return done
