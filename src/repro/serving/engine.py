"""Serving engine: batched prefill + decode with slot management.

The decode step is a single compiled program over a fixed batch of
*lanes*; requests are multiplexed onto free lanes (continuous-batching
style).  Each lane tracks its own absolute position, so mixed-progress
lanes decode together in one program — ring caches and the position-
masked attention make this correct (slots whose ``pos`` is -1 never
attend).

``serve_step`` (= one ``decode_step`` over the full lane batch) is what
the ``decode_*`` / ``long_*`` dry-run shapes lower.

The lane-cache helpers (:func:`lane_slice`, :func:`lane_write`,
:func:`reset_lane`) are module-level so the disaggregated tier
(:mod:`repro.serving.disagg`) runs the *same* per-lane prefill path on
its prefill workers — bit-identical caches are what make migrated-KV
decode match the single-host oracle exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.events import EventMailbox, SlotEvent
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


# --------------------------------------------------------------------------
# lane-cache plumbing (shared with the disaggregated prefill workers)
# --------------------------------------------------------------------------

def lane_slice(cache, lane):
    """Slice one lane's cache view (B=1 on axis 1) out of a full cache."""
    return jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, lane, 1, axis=1)
        if c.ndim >= 2 else c, cache)


def lane_write(cache, lane_cache, lane):
    """Write a (B=1) lane cache back into the full cache at ``lane``."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), lane, axis=1)
        if full.ndim >= 2 else one, cache, lane_cache)


def reset_lane(cache, lane: int):
    """Clear a lane's cache before reuse: position slots to -1 (so the
    masked attention ignores them), recurrent states to their inits."""

    def reset(path, c):
        if c.ndim < 2:
            return c
        name = str(getattr(path[-1], "key", path[-1]))
        lane_shape = c.shape[:1] + (1,) + c.shape[2:]
        if name == "pos":
            fresh = -jnp.ones(lane_shape, c.dtype)
        elif name == "m":
            fresh = jnp.full(lane_shape, -30.0, c.dtype)
        else:
            fresh = jnp.zeros(lane_shape, c.dtype)
        return jax.lax.dynamic_update_slice_in_dim(c, fresh, lane, axis=1)

    return jax.tree_util.tree_map_with_path(reset, cache)


class ServeEngine:
    def __init__(self, model: Model, params, lanes: int, slots: int,
                 greedy: bool = True, temperature: float = 1.0, seed: int = 0,
                 event_sink=None, event_watermark: int = 64):
        self.model = model
        self.params = params
        self.lanes = lanes
        self.slots = slots
        self.greedy = greedy
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        # slot accounting goes through a mailbox: acquire/release events
        # batch up and reach event_sink once per decode step (phase
        # boundary), not once per lane transition
        self.events = EventMailbox(watermark=event_watermark,
                                   sink=event_sink)

        self.cache = model.make_cache(lanes, slots)
        self.pos = np.zeros((lanes,), np.int32)
        self.last_tok = np.zeros((lanes,), np.int32)
        self.active: list[Request | None] = [None] * lanes
        self._decode = jax.jit(model.decode_step)
        # single-lane prefill (prompts have ragged lengths; each fills its
        # own lane's cache slice)
        self._prefill_one = jax.jit(self._prefill_lane)
        self._adopt = jax.jit(lane_write)

    # -- lane-granular prefill ------------------------------------------------

    def _prefill_lane(self, params, cache, tokens, lane):
        """Run a (1, S) prompt and write its cache into lane ``lane``."""
        lane_cache = lane_slice(cache, lane)
        logits, lane_cache = self.model.prefill(params, {"tokens": tokens},
                                                lane_cache)
        cache = lane_write(cache, lane_cache, lane)
        return logits, cache

    # -- scheduling -------------------------------------------------------------

    def _reset_lane(self, lane: int):
        self.cache = reset_lane(self.cache, lane)

    def find_free_lane(self) -> int | None:
        """Lowest free lane index, or None when saturated."""
        for lane, cur in enumerate(self.active):
            if cur is None:
                return lane
        return None

    def submit(self, req: Request) -> bool:
        """Place a request on a free lane (prefill now).  False if full."""
        lane = self.find_free_lane()
        if lane is None:
            return False
        self._reset_lane(lane)
        self.active[lane] = req
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, self.cache = self._prefill_one(
            self.params, self.cache, toks, lane)
        tok = self._sample(np.asarray(logits)[0])
        req.out.append(int(tok))
        self.pos[lane] = len(req.prompt)
        self.last_tok[lane] = tok
        self.events.send(SlotEvent("acquire", lane, req.rid))
        return True

    def adopt_lane(self, lane: int, lane_cache, req: Request, *,
                   pos: int, last_tok: int) -> None:
        """Attach an externally prefilled request to ``lane``.

        ``lane_cache`` is a (B=1) cache pytree — in the disaggregated
        tier it is read back out of this kernel's PGAS segment after a
        prefill worker migrated it in with one vectored put.  The lane
        is NOT reset first: adoption overwrites every cache leaf.
        """
        if self.active[lane] is not None:
            raise ValueError(f"adopt_lane: lane {lane} is busy "
                             f"(rid={self.active[lane].rid})")
        self.cache = self._adopt(self.cache, lane_cache, lane)
        self.active[lane] = req
        self.pos[lane] = pos
        self.last_tok[lane] = last_tok
        self.events.send(SlotEvent("acquire", lane, req.rid))

    def _sample(self, logits: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self):
        """One decode step for all active lanes."""
        if not any(r is not None and not r.done for r in self.active):
            return
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        logits = np.asarray(logits, np.float32)
        for lane, req in enumerate(self.active):
            if req is None or req.done:
                continue
            tok = self._sample(logits[lane])
            req.out.append(tok)
            self.pos[lane] += 1
            self.last_tok[lane] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[lane] = None
                self.events.send(SlotEvent("release", lane, req.rid))
        # phase boundary: this step's slot events go out as one batch
        self.events.flush()

    @property
    def idle(self) -> bool:
        return all(r is None for r in self.active)

    def drain(self):
        """Force-deliver pending slot events when the request stream ends.

        ``step`` flushes at its phase boundary, but a stream can end
        with events still below the watermark (e.g. a final ``submit``
        whose acquire never met another step, or callers driving
        ``submit``/``step`` directly).  Without an explicit drain those
        trailing events were silently dropped; every exit path must end
        here.  Returns the final delivered batch.
        """
        return self.events.flush()

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a request list to completion (simple FCFS scheduler)."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        self.drain()
        return done
