"""KV caches as PGAS Shoal segments (the disaggregated-serving store).

``model.make_cache`` builds a pytree of per-lane ring caches.  For the
disaggregated tier that state must be able to *move* — a finished
prefill's KV migrates from a prefill kernel to a free decode lane — so
:class:`KvSegmentSpace` gives every lane a fixed region of the global
address space and a trace-time-resolved layout inside it:

    lane base address   = lane * lane_words
    leaf offset         = running word offset of the cache leaf (static
                          flatten order of the cache pytree)
    layer stride        = words-per-layer of that leaf (the leading
                          ``reps`` scan dim of a stacked cache leaf)

so the address of (lane, leaf, layer) is a Python int at trace time —
the global->local translation is specialized into the compiled program,
exactly the hardware-address-mapping argument the UPC study makes
(PAPERS.md), and the whole lane migrates as ONE ``put_long_vectored``
whose per-layer destination addresses ride in-packet (PR 1's fused wire
format).  No gather/scatter collective, no per-layer message.

Word encoding: segments are float32 word arrays; cache leaves are
*value-cast* onto them (bf16/f16 -> f32 is exact, int32 ring positions
are exact for |v| < 2**24, i.e. any realistic slot count).  A bitcast
would be byte-faithful but NaN-hazardous: int bit patterns reinterpreted
as floats can be NaN-canonicalized by the masking arithmetic on the
egress path, so the value cast is the bit-identity-preserving choice.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops
from repro.core.address_space import GlobalAddressSpace
from repro.core.state import PgasState

# credit token reserved for KV migrations (separate from app traffic so
# wait_replies on a migration never drains an application credit)
MIGRATE_TOKEN = 3


@dataclasses.dataclass(frozen=True)
class KvLeaf:
    """Layout of one cache-pytree leaf inside a lane's segment region."""

    path: str                     # human-readable pytree path
    layers: int                   # leading scan (reps) dim
    shape: tuple[int, ...]        # per-lane per-layer shape
    dtype: object                 # original leaf dtype
    words: int                    # words per layer (= layer stride)
    offset: int                   # word offset inside the lane region

    @property
    def total_words(self) -> int:
        return self.layers * self.words


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


class KvSegmentSpace:
    """Places ``lanes`` ring KV caches into PGAS segments.

    Every decode kernel uses the same layout over its own segment, so a
    prefill kernel can compute a migration's destination addresses
    locally from ``(lane,)`` alone — locality is explicit, per the
    paper's Sec. II-A3 contract.
    """

    def __init__(self, gas: GlobalAddressSpace, model, *, lanes: int,
                 slots: int):
        self.gas = gas
        self.ctx = gas.ctx
        self.lanes = int(lanes)
        self.slots = int(slots)
        proto = model.make_cache(1, slots)
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(proto)
        if not flat:
            raise ValueError("model cache has no leaves to place in the "
                             "address space")
        leaves: list[KvLeaf] = []
        off = 0
        for path, leaf in flat:
            if leaf.ndim < 2 or leaf.shape[1] != 1:
                raise ValueError(
                    f"cache leaf {_path_str(path)} has shape {leaf.shape}; "
                    "expected (layers, lane, ...) stacked cache state")
            words = math.prod(leaf.shape[2:]) if leaf.ndim > 2 else 1
            leaves.append(KvLeaf(
                path=_path_str(path), layers=int(leaf.shape[0]),
                shape=tuple(leaf.shape[2:]), dtype=leaf.dtype,
                words=int(words), offset=off))
            off += int(leaf.shape[0]) * int(words)
        self.leaves = tuple(leaves)
        self.lane_words = off
        need = self.lanes * self.lane_words
        if need > self.ctx.segment_words:
            raise ValueError(
                f"KvSegmentSpace needs {need} words ({self.lanes} lanes x "
                f"{self.lane_words} words/lane) but segments hold only "
                f"{self.ctx.segment_words}")
        n_blocks = sum(leaf.layers for leaf in self.leaves)
        if self.lane_words + n_blocks > self.ctx.transport.max_packet_words:
            raise ValueError(
                f"one KV lane ({self.lane_words} payload words + "
                f"{n_blocks} vectored addresses) exceeds the transport "
                f"MTU ({self.ctx.transport.max_packet_words} words); "
                "vectored puts do not segment — shrink slots or raise "
                "max_packet_bytes")

    # -- addressing (all Python ints: resolved at trace time) ---------------

    def lane_base(self, lane: int) -> int:
        if not 0 <= lane < self.lanes:
            raise ValueError(f"lane {lane} out of range ({self.lanes} lanes)")
        return lane * self.lane_words

    def block_addrs(self, lane: int, *, kernel: int = 0) -> list[int]:
        """Per-(leaf, layer) destination addresses for migrating one lane
        into ``kernel``'s segment — the vectored address list that rides
        in-packet.  Validated against the owner's segment bounds."""
        base = self.lane_base(lane)
        addrs: list[int] = []
        for leaf in self.leaves:
            addrs.extend(self.gas.vectored_addrs(
                kernel, base + leaf.offset,
                [leaf.words] * leaf.layers, stride=leaf.words))
        return addrs

    # -- pack / unpack -------------------------------------------------------

    def pack_lane(self, lane_cache) -> list[jnp.ndarray]:
        """Flatten a (B=1) lane cache into per-(leaf, layer) segment-word
        blocks, ordered to match :meth:`block_addrs`."""
        flat, treedef = jax.tree_util.tree_flatten(lane_cache)
        if treedef != self._treedef:
            raise ValueError(
                "lane cache structure does not match this KvSegmentSpace "
                f"layout: {treedef} != {self._treedef}")
        seg_dtype = jnp.dtype(self.gas.dtype)
        blocks: list[jnp.ndarray] = []
        for leaf_meta, leaf in zip(self.leaves, flat):
            rows = leaf.reshape(leaf_meta.layers, leaf_meta.words)
            blocks.extend(rows[l].astype(seg_dtype)
                          for l in range(leaf_meta.layers))
        return blocks

    def unpack_lane(self, segment_row, lane: int):
        """Rebuild a (B=1) lane cache pytree from one kernel's segment
        words (the decode-side view refresh after a migration landed)."""
        base = self.lane_base(lane)
        seg = jnp.asarray(segment_row)
        leaves = []
        for leaf in self.leaves:
            flat = jax.lax.dynamic_slice(
                seg, (base + leaf.offset,), (leaf.total_words,))
            leaves.append(flat.reshape((leaf.layers, 1) + leaf.shape)
                          .astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- migration -----------------------------------------------------------

    def migrate(self, state: PgasState, blocks, pattern, lane: int, *,
                token: int = MIGRATE_TOKEN, wait: bool = True) -> PgasState:
        """One finished prefill's KV -> a decode lane, as ONE vectored put.

        Runs inside the SPMD program: ``pattern`` is the static
        ``[(prefill_kernel, decode_kernel)]`` link, ``blocks`` the
        :meth:`pack_lane` output, and the per-layer destination address
        list is resolved here at trace time and shipped in-packet.  On
        an acked transport the single coalesced reply is awaited on the
        migration token, so the decode side's adoption is ordered after
        the write.
        """
        dst = pattern[-1][1]
        addrs = self.block_addrs(lane, kernel=dst)
        state = ops.put_long_vectored(self.ctx, state, list(blocks), pattern,
                                      addrs, token=token)
        if wait and self.ctx.transport.acked:
            # only the prefill side gets the reply; waiting for n=1 on
            # every kernel would raise the underflow bit on the rest
            n = ops._is_sender(self.ctx, pattern).astype(jnp.int32)
            state = ops.wait_replies(self.ctx, state, token=token, n=n)
        return state

    def describe(self) -> str:
        """Human-readable layout table (README / debugging aid)."""
        lines = [f"lane_words={self.lane_words} lanes={self.lanes} "
                 f"segment_words={self.ctx.segment_words}"]
        for leaf in self.leaves:
            lines.append(
                f"  +{leaf.offset:<6} {leaf.path}: {leaf.layers} layers x "
                f"{leaf.words} words (shape {leaf.shape}, "
                f"{jnp.dtype(leaf.dtype).name})")
        return "\n".join(lines)
