"""Admission front-end: the serving tier's entry point with backpressure.

The engine (single-host or disaggregated) exposes ``submit``/``step``;
what production traffic needs on top is *admission control*: a bounded
job queue, explicit rejection when the queue is full (backpressure the
caller can see, instead of unbounded latency), request status, and a
runner that keeps lanes fed.  That is this module — the Shoal analogue
of a web tier's job queue + worker loop.

Lane accounting flows through the engine's existing
:class:`~repro.actors.events.EventMailbox`: the front-end chains itself
onto the sink, so one batched event delivery per decode step updates
job states and the busy-lane set — no per-token polling of request
objects.

Thread model: ``submit`` and the runner are lock-serialized, so the
front-end can be driven synchronously (``pump`` / ``run_until_idle``,
what the tests and benchmarks do) or by a background runner thread
(``start`` / ``stop``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.actors.events import SlotEvent
from repro.serving.engine import Request

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
TIMED_OUT = "timed_out"


@dataclasses.dataclass
class Job:
    """One admitted (or rejected) generation request."""

    rid: int
    request: Request
    status: str = QUEUED
    deadline: float | None = None     # absolute time.monotonic() cutoff

    @property
    def tokens(self) -> list[int]:
        return self.request.out


class ServeFrontend:
    """Bounded admission queue over a serving engine.

    Args:
      engine: anything with the ``ServeEngine`` scheduler surface
        (``submit(Request) -> bool``, ``step()``, ``drain()``, ``idle``)
        — the single-host engine or the disaggregated tier.
      max_queue: admission bound.  ``submit`` beyond it returns a
        REJECTED job immediately — the backpressure contract; queued
        depth never exceeds this.
      events: the engine's EventMailbox(es) to chain onto for slot
        accounting; defaults to ``engine.events`` when present.
    """

    def __init__(self, engine, *, max_queue: int = 64, events=None):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.engine = engine
        self.max_queue = int(max_queue)
        self._queue: deque[Job] = deque()
        self.jobs: dict[int, Job] = {}
        self._next_rid = 0
        self._lock = threading.RLock()
        self._runner: threading.Thread | None = None
        self._stop = threading.Event()
        # stats
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.expired = 0
        self.peak_queue_depth = 0
        self.busy_lanes: set[tuple] = set()
        self._chain_events(events)

    # -- event-mailbox integration -------------------------------------------

    def _chain_events(self, events) -> None:
        if events is None:
            mailboxes = []
            if hasattr(self.engine, "events"):
                mailboxes = [(None, self.engine.events)]
            elif hasattr(self.engine, "engines"):
                mailboxes = [(did, eng.events)
                             for did, eng in self.engine.engines.items()]
        else:
            mailboxes = [(None, mb) for mb in events]
        for tag, mb in mailboxes:
            prev = mb.sink
            mb.sink = self._make_sink(tag, prev)

    def _make_sink(self, tag, prev):
        def sink(batch):
            self._on_events(tag, batch)
            if prev is not None:
                prev(batch)
        return sink

    def _on_events(self, tag, batch: list[SlotEvent]) -> None:
        """One batched delivery per engine flush (the mailbox contract):
        acquire/release events drive job state, never per-token polls."""
        with self._lock:
            for e in batch:
                key = (tag, e.lane)
                if e.kind == "acquire":
                    self.busy_lanes.add(key)
                elif e.kind == "release":
                    self.busy_lanes.discard(key)
                    job = self.jobs.get(e.rid)
                    if job is not None and job.status != DONE:
                        job.status = DONE
                        self.completed += 1

    # -- admission -----------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def submit(self, prompt, max_new: int, *, deadline_s: float | None = None,
               retries: int = 0, backoff_s: float = 0.002) -> Job:
        """Admit a request, or reject it when the queue is full.

        Graceful degradation instead of a hard cliff: ``retries`` > 0
        re-attempts a full-queue admission with exponential backoff
        (``backoff_s`` doubling each attempt, lock released while
        sleeping) before giving up with REJECTED, and ``deadline_s``
        bounds how long the job may sit unfinished — :meth:`pump`
        expires overdue queued jobs to TIMED_OUT rather than serving
        them arbitrarily late.  With the defaults the call never blocks
        and the queue never grows past ``max_queue``."""
        delay = float(backoff_s)
        for attempt in range(int(retries) + 1):
            with self._lock:
                if len(self._queue) < self.max_queue:
                    rid = self._next_rid
                    self._next_rid += 1
                    req = Request(rid=rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new=int(max_new))
                    job = Job(rid=rid, request=req, status=QUEUED,
                              deadline=(None if deadline_s is None
                                        else time.monotonic() + deadline_s))
                    self.jobs[rid] = job
                    self._queue.append(job)
                    self.admitted += 1
                    self.peak_queue_depth = max(self.peak_queue_depth,
                                                len(self._queue))
                    return job
            if attempt < retries:
                time.sleep(delay)     # outside the lock: let pump() drain
                delay *= 2
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = Request(rid=rid,
                          prompt=np.asarray(prompt, np.int32),
                          max_new=int(max_new))
            job = Job(rid=rid, request=req, status=REJECTED)
            self.jobs[rid] = job
            self.rejected += 1
            return job

    def status(self, rid: int) -> str:
        with self._lock:
            job = self.jobs.get(rid)
            if job is None:
                raise KeyError(f"unknown rid {rid}")
            return job.status

    def result(self, rid: int) -> list[int] | None:
        """Generated tokens once DONE, else None (REJECTED raises)."""
        with self._lock:
            job = self.jobs.get(rid)
            if job is None:
                raise KeyError(f"unknown rid {rid}")
            if job.status == REJECTED:
                raise ValueError(f"rid {rid} was rejected (queue full)")
            if job.status == TIMED_OUT:
                raise ValueError(f"rid {rid} timed out before admission "
                                 "(deadline_s elapsed in the queue)")
            return list(job.tokens) if job.status == DONE else None

    # -- the runner ----------------------------------------------------------

    def pump(self) -> bool:
        """One scheduler turn: expire overdue queued jobs, admit the
        rest onto free lanes, then one decode step.  Returns True if
        any work remains."""
        with self._lock:
            now = time.monotonic()
            while self._queue:
                job = self._queue[0]
                if job.deadline is not None and now > job.deadline:
                    # overdue before it ever ran: shed it rather than
                    # serve a response nobody is waiting for anymore
                    job.status = TIMED_OUT
                    self.expired += 1
                    self._queue.popleft()
                    continue
                if not self.engine.submit(job.request):
                    break   # decode lanes saturated: jobs wait, queue bounded
                job.status = RUNNING
                self._queue.popleft()
            self.engine.step()
            return bool(self._queue) or not self.engine.idle

    def run_until_idle(self) -> None:
        """Synchronous drive to completion (tests / benchmarks)."""
        while self.pump():
            pass
        with self._lock:
            self.engine.drain()

    def start(self, poll_s: float = 0.001) -> None:
        """Background runner thread: pump while work exists, nap when
        idle.  ``stop()`` ends it and drains the engine's mailboxes."""
        if self._runner is not None:
            raise RuntimeError("runner already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.pump():
                    time.sleep(poll_s)

        self._runner = threading.Thread(target=loop, daemon=True,
                                        name="serve-frontend")
        self._runner.start()

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the runner and drain the engine.  ``join`` returning is
        NOT success — a wedged runner leaves it alive past the timeout,
        and silently continuing would drain the engine under a thread
        still pumping it.  Raises RuntimeError in that case (the runner
        is kept so a later ``stop`` can retry)."""
        if self._runner is None:
            return
        self._stop.set()
        self._runner.join(timeout)
        if self._runner.is_alive():
            raise RuntimeError(
                f"serve-frontend runner failed to stop within {timeout}s "
                "(thread still alive; engine NOT drained)")
        self._runner = None
        with self._lock:
            self.engine.drain()

    def stats(self) -> dict:
        with self._lock:
            return dict(admitted=self.admitted, rejected=self.rejected,
                        completed=self.completed, expired=self.expired,
                        peak_queue_depth=self.peak_queue_depth,
                        queue_depth=len(self._queue),
                        busy_lanes=len(self.busy_lanes))
