"""Disaggregated serving tier: prefill and decode on disjoint mesh slices.

The single-host :class:`~repro.serving.engine.ServeEngine` interleaves
prefill and decode on one program; at production scale they fight — a
long prompt stalls every decoding lane.  The disaggregated tier splits
the kernel axis into a prefill slice and a decode slice
(:class:`repro.launch.mesh.ServingSlices`) and moves a finished
prefill's KV to a free decode lane as ONE one-sided
``put_long_vectored`` into the decode kernel's PGAS segment
(:class:`~repro.serving.kv_space.KvSegmentSpace` fixes the per-lane /
per-layer layout at trace time), instead of a gather/scatter collective.

Emulation note: kernels here are devices of one host mesh (the same
emulation the comm benchmarks use), so "a prefill worker" is a
host-driven jitted program and the migration is the compiled SPMD
program over the kernel mesh.  The wire cost is still the *measured*
HLO of that program — ≤ 2 collective-permutes per migration (1 fused
vectored packet + 1 coalesced reply), asserted by
``tests/serving_checks.py`` and the ``--serving`` benchmark mode.

Bit-identity contract: a migrated request decodes to exactly the tokens
the single-host engine produces, because (a) prefill workers run the
same ``reset_lane`` + per-lane prefill path as the engine and (b) the
segment round trip is value-exact (see :mod:`repro.serving.kv_space`).
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.address_space import GlobalAddressSpace
from repro.core.state import ShoalContext
from repro.launch.mesh import ServingSlices, make_serving_mesh
from repro.runtime.jax_compat import shard_map
from repro.runtime.transport import TCP
from repro.serving.engine import Request, ServeEngine, lane_slice, reset_lane
from repro.serving.kv_space import MIGRATE_TOKEN, KvSegmentSpace


class PrefillWorker:
    """One prefill kernel: ragged-prompt prefill into a single-lane cache.

    Deliberately reuses the engine's lane helpers (``reset_lane`` +
    ``lane_slice`` + ``model.prefill``) so its compiled program computes
    the same values the single-host engine's ``_prefill_lane`` does —
    the precondition for bit-identical migrated decode.
    """

    def __init__(self, model, params, slots: int, kernel_id: int):
        self.model = model
        self.params = params
        self.kernel_id = kernel_id
        self._cache0 = model.make_cache(1, slots)
        self.prefills = 0

        def _pf(params, cache, toks):
            lc = lane_slice(cache, 0)
            logits, lc = model.prefill(params, {"tokens": toks}, lc)
            return logits, lc

        self._prefill = jax.jit(_pf)

    def prefill(self, prompt: np.ndarray):
        """Returns ``(last_logits (vocab,), lane_cache)`` for one prompt."""
        cache = reset_lane(self._cache0, 0)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, lane_cache = self._prefill(self.params, cache, toks)
        self.prefills += 1
        return logits[0], lane_cache


class DisaggServeTier:
    """Prefill slice + PGAS KV migration + decode slice.

    Duck-types the :class:`ServeEngine` scheduler surface (``submit`` /
    ``step`` / ``drain`` / ``idle`` / ``run``) so the admission
    front-end (:mod:`repro.serving.frontend`) drives either tier.
    """

    def __init__(self, model, params, slices: ServingSlices, *,
                 lanes_per_decode: int, slots: int, transport=TCP,
                 segment_words: int | None = None, mesh=None,
                 greedy: bool = True, seed: int = 0, event_sink=None):
        self.model = model
        self.params = params
        self.slices = slices
        self.mesh = mesh if mesh is not None else make_serving_mesh(slices)
        probe_words = _lane_words(model, slots)
        if segment_words is None:
            segment_words = lanes_per_decode * probe_words
        self.ctx = ShoalContext(mesh=self.mesh, axes=(slices.axis,),
                                transport=transport,
                                segment_words=segment_words)
        self.gas = GlobalAddressSpace(self.ctx)
        self.kv = KvSegmentSpace(self.gas, model, lanes=lanes_per_decode,
                                 slots=slots)
        self.state = self.gas.make_global_state()
        self.workers = {pid: PrefillWorker(model, params, slots, pid)
                        for pid in slices.prefill_ids}
        self._next_prefill = itertools.cycle(slices.prefill_ids)
        self.engines = {
            did: ServeEngine(model, params, lanes=lanes_per_decode,
                             slots=slots, greedy=greedy, seed=seed + did,
                             event_sink=event_sink)
            for did in slices.decode_ids}
        self._migrations: dict[tuple[int, int, int], object] = {}
        self.migrations = 0

    # -- migration program cache ------------------------------------------------

    def _migration(self, src: int, dst: int, lane: int):
        """Compiled SPMD migration program for one (src, dst, lane)."""
        key = (src, dst, lane)
        fn = self._migrations.get(key)
        if fn is None:
            pattern = self.slices.migration_pattern(src, dst)
            ctx, kv = self.ctx, self.kv
            spec = P(ctx.axes)

            def inner(state, blocks):
                state = jax.tree.map(lambda x: x[0], state)
                state = kv.migrate(state, blocks, pattern, lane,
                                   token=MIGRATE_TOKEN)
                return jax.tree.map(lambda x: x[None], state)

            fn = jax.jit(shard_map(inner, mesh=ctx.mesh,
                                   in_specs=(spec, P()), out_specs=spec))
            self._migrations[key] = fn
        return fn

    def migration_hlo(self, src: int, dst: int, lane: int = 0) -> str:
        """Optimized HLO of one migration (for collective-budget gates)."""
        blocks = tuple(self.kv.pack_lane(
            lane_slice(self.workers[src]._cache0, 0)))
        fn = self._migration(src, dst, lane)
        return fn.lower(self.state, blocks).compile().as_text()

    # -- scheduling (ServeEngine duck type) --------------------------------------

    @property
    def active(self):
        return [r for eng in self.engines.values() for r in eng.active]

    @property
    def idle(self) -> bool:
        return all(eng.idle for eng in self.engines.values())

    def find_free_lane(self):
        for did, eng in self.engines.items():
            lane = eng.find_free_lane()
            if lane is not None:
                return did, lane
        return None

    def submit(self, req: Request) -> bool:
        """Prefill on the prefill slice, migrate KV, adopt on decode.

        False when every decode lane is busy (the front-end's
        backpressure signal)."""
        slot = self.find_free_lane()
        if slot is None:
            return False
        did, lane = slot
        src = next(self._next_prefill)
        logits, lane_cache = self.workers[src].prefill(req.prompt)
        eng = self.engines[did]
        tok = eng._sample(np.asarray(logits))
        # ONE one-sided vectored put: lane KV -> decode kernel's segment
        blocks = tuple(self.kv.pack_lane(lane_cache))
        self.state = self._migration(src, did, lane)(self.state, blocks)
        self.migrations += 1
        # decode-side view refresh: the lane cache now lives in the PGAS
        # segment; the engine adopts it from there
        seg_row = np.asarray(jax.device_get(self.state.segment))[did]
        req.out.append(int(tok))
        eng.adopt_lane(lane, self.kv.unpack_lane(seg_row, lane), req,
                       pos=len(req.prompt), last_tok=int(tok))
        return True

    def step(self):
        for eng in self.engines.values():
            eng.step()

    def drain(self):
        out = []
        for eng in self.engines.values():
            out.extend(eng.drain())
        return out

    def run(self, requests: list[Request]) -> list[Request]:
        """FCFS to completion — same scheduler loop as the single-host
        engine, so token outputs are comparable request-for-request."""
        pending = list(requests)
        done: list[Request] = []
        while pending or not self.idle:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        self.drain()
        return done


def _lane_words(model, slots: int) -> int:
    """Words one lane's cache occupies in a segment (layout probe)."""
    proto = model.make_cache(1, slots)
    total = 0
    for leaf in jax.tree_util.tree_leaves(proto):
        if leaf.ndim < 2:
            raise ValueError("cache leaf with no lane dim")
        per_layer = 1
        for d in leaf.shape[2:]:
            per_layer *= d
        total += leaf.shape[0] * per_layer
    return total
