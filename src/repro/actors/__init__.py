"""Actor layer: per-destination small-message aggregation over Shoal AMs.

The paper's PGAS model pays one network transaction per active message,
which is ruinous for header-sized control traffic (MoE routing metadata,
credit returns, serve-engine slot events).  Following the
scalable-actors-on-PGAS line of work (and DART-MPI's aggregation
argument), this package adds mailbox objects that append tiny messages
into a per-destination packet stack — PR 1's ``(nseg, HDR+W)`` fused
wire format — and flush the whole stack as ONE collective on a
watermark or an explicit phase boundary.

* :class:`~repro.actors.mailbox.Mailbox` — device-side mailbox: N tiny
  Short/Long AMs to one destination cost one ``ppermute`` (plus, on an
  acked transport, one coalesced reply for the whole flush).
* :class:`~repro.actors.mailbox.MultiMailbox` — one mailbox over
  several destination patterns: sub-stacks of patterns with disjoint
  source/destination sets concatenate and flush as one collective per
  group, with one counted reply per group acking every pattern.
* :class:`~repro.actors.mailbox.ReplyMailbox` — defers the auto-replies
  of ordinary puts and returns all owed credits per destination as one
  Short AM.
* :class:`~repro.actors.events.EventMailbox` — host-side equivalent for
  control-plane events (serve-engine slot accounting).
* :mod:`~repro.actors.coalesce` — bit-exact metadata-lane packing so an
  int sideband rides inside an existing payload collective instead of
  being its own collective (MoE token routing).
"""

from repro.actors.coalesce import pack_meta_lane, unpack_meta_lane
from repro.actors.events import EventMailbox, SlotEvent
from repro.actors.mailbox import Mailbox, MultiMailbox, ReplyMailbox

__all__ = [
    "Mailbox",
    "MultiMailbox",
    "ReplyMailbox",
    "EventMailbox",
    "SlotEvent",
    "pack_meta_lane",
    "unpack_meta_lane",
]
