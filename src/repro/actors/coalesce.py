"""Bit-exact metadata-lane packing.

A recurring small-message pattern is an int sideband that travels next
to a payload collective: MoE expert IDs alongside routed tokens, slot
indices alongside activations.  Shipping the sideband as its own
collective doubles the message count; casting it into the payload dtype
silently corrupts values the mantissa cannot hold.  These helpers
*bitcast* ints into payload-typed lanes instead — the same lossless
trick the fused wire format uses for payloads (:func:`repro.core.am.to_wire`)
— so the metadata rides INSIDE the existing collective as one extra
lane, bit-exact both ways.

4-byte payload dtypes (f32/i32/u32) carry a full int32 per lane; 2-byte
dtypes (bf16/f16) carry an int16 per lane, so values must fit in
[-32768, 32767] — plenty for expert/slot indices, asserted nowhere
because lanes are traced (callers own the range contract).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def pack_meta_lane(meta: jnp.ndarray, dtype) -> jnp.ndarray:
    """Bitcast int metadata into lanes of ``dtype`` (the payload dtype).

    Returns an array of ``meta.shape`` and ``dtype`` whose *bits* are
    the metadata — pass it through any bit-preserving transport (an
    all_to_all, a ppermute, a fused packet) and recover it with
    :func:`unpack_meta_lane`.
    """
    dt = jnp.dtype(dtype)
    if dt.itemsize == 4:
        return lax.bitcast_convert_type(meta.astype(jnp.int32), dt)
    if dt.itemsize == 2:
        return lax.bitcast_convert_type(meta.astype(jnp.int16), dt)
    raise TypeError(
        f"cannot pack int metadata into {dt} lanes (need 2- or 4-byte "
        "payload dtype)")


def unpack_meta_lane(lane: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_meta_lane`; always returns int32."""
    itemsize = jnp.dtype(lane.dtype).itemsize
    if itemsize == 4:
        return lax.bitcast_convert_type(lane, jnp.int32)
    if itemsize == 2:
        return lax.bitcast_convert_type(lane, jnp.int16).astype(jnp.int32)
    raise TypeError(
        f"cannot unpack int metadata from {jnp.dtype(lane.dtype)} lanes")
