"""Mailboxes: trace-time aggregation of tiny AMs into one packet stack.

A :class:`Mailbox` is bound to one ``pattern`` (who talks to whom this
phase) and a fixed per-message word capacity.  ``send`` appends a
message — a header-field record plus a zero-padded payload row — into
the pending stack; when the stack reaches the watermark (or ``flush`` is
called at a phase boundary) the whole stack ships as ONE fused
``(n, HDR_WORDS + msg_words)`` collective and is absorbed by the
mixed-class scanned GAScore ingress (:func:`repro.core.gascore.ingress_stack`).
N tiny messages therefore cost one ``ppermute`` instead of N — the
actor-style aggregation buffer, built directly on PR 1's batched >MTU
wire format.

Reply coalescing: on an acked transport every row in the stack is
marked async except the last, whose ack token is forced to the
*mailbox* token — so one flush earns exactly ONE credit on
``mailbox.token``, regardless of how many messages it carried or what
per-message tokens/flags they used.  ``wait_replies(token=mb.token,
n=mb.flushes)`` is the phase-boundary fence.

Mailboxes are trace-time objects: create them inside the traced program
(or flush before a trace boundary).  Payload rows and header fields stay
concrete numpy whenever the caller passes concrete values, so a
1024-message flush lowers to one constant, not 1024 stacked ops.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.analysis import trace as _lint
from repro.core import am
from repro.core import gascore as gc
from repro.core import handlers as hd
from repro.core import ops
from repro.core.state import PgasState, ShoalContext

DEFAULT_WATERMARK = 64

# header fields a mailbox records per message (src/dst/seq are uniform
# across the stack and broadcast at flush time)
_ROW_FIELDS = ("type", "nwords", "dst_addr", "handler", "token")


def _is_concrete(x) -> bool:
    return isinstance(x, (int, float, np.integer, np.floating, np.ndarray,
                          list, tuple))


class Mailbox:
    """Per-destination coalescing mailbox over a Shoal context.

    Args:
      ctx: the Shoal context (transport decides acked/async flushes).
      pattern: static ``[(src, dst), ...]`` the stack ships along.
      msg_words: payload word capacity per message (rows are zero-padded
        to this width; Short rows carry zeros).
      watermark: pending-message count that triggers an automatic flush
        from inside ``send``; ``flush`` may be called earlier at any
        phase boundary.
      token: credit token the per-flush ack lands on.
      dtype: payload dtype (must be 32-bit to bitcast onto the wire).
      reply_via: optional :class:`ReplyMailbox` to defer even the
        one-per-flush ack into.
    """

    def __init__(self, ctx: ShoalContext, pattern, *, msg_words: int,
                 watermark: int = DEFAULT_WATERMARK, token: int = 0,
                 dtype=jnp.float32, reply_via=None):
        if not am.wire_dtype_ok(dtype):
            raise TypeError(
                f"mailbox payload dtype must be 32-bit (wire bitcast), "
                f"got {jnp.dtype(dtype)}")
        if msg_words < 1:
            raise ValueError("msg_words must be >= 1")
        if watermark < 1:
            raise ValueError("watermark must be >= 1")
        self.ctx = ctx
        self.pattern = list(pattern)
        self.msg_words = int(msg_words)
        self.watermark = int(watermark)
        self.token = int(token)
        self.dtype = jnp.dtype(dtype)
        self.reply_via = reply_via
        self._fields: list[dict] = []
        self._payloads: list = []
        self._lint_rows: list[tuple] = []   # (class, addr, nwords, handler, token)
        self._tx_words = 0
        self.flushes = 0
        self.msgs_sent = 0

    @property
    def pending(self) -> int:
        return len(self._fields)

    def __len__(self) -> int:
        return len(self._fields)

    # -- enqueue ---------------------------------------------------------------

    def _pad_row(self, payload):
        """Zero-pad one payload to (msg_words,); numpy stays numpy so an
        all-concrete stack lowers to a single constant at flush."""
        if _is_concrete(payload):
            row = np.asarray(payload, self.dtype).reshape(-1)
            if row.size > self.msg_words:
                raise ValueError(
                    f"mailbox message of {row.size} words exceeds msg_words="
                    f"{self.msg_words}; use put_long for big messages")
            return np.pad(row, (0, self.msg_words - row.size)), row.size
        row = jnp.asarray(payload, self.dtype).reshape(-1)
        if row.size > self.msg_words:
            raise ValueError(
                f"mailbox message of {row.size} words exceeds msg_words="
                f"{self.msg_words}; use put_long for big messages")
        return jnp.pad(row, (0, self.msg_words - row.size)), row.size

    def send(self, state: PgasState, payload=None, *, dst_addr=0,
             handler=hd.H_WRITE, msg_class: int = am.LONG, token=None,
             arg=1) -> PgasState:
        """Append one tiny AM to the pending stack.

        Long messages land ``payload`` in the destination segment at
        ``dst_addr`` through ``handler``; Short messages (no payload)
        run ``handler`` on the destination's credit word ``token`` with
        ``arg`` — the signaling/credit-return class.  Returns ``state``
        unchanged unless the watermark triggers an automatic flush.
        """
        if msg_class == am.SHORT:
            if payload is not None:
                raise ValueError("Short mailbox messages carry no payload")
            row, nwords = (np.zeros((self.msg_words,), self.dtype), 0)
            dst_addr = arg                       # Short: dst_addr = handler arg
        elif msg_class == am.LONG:
            if payload is None:
                raise ValueError("Long mailbox messages need a payload")
            row, nwords = self._pad_row(payload)
        else:
            raise ValueError(
                "mailboxes aggregate Short and Long AMs; Medium delivery "
                "(payload to kernel) has no coalesced ingress")
        t = am.make_type(msg_class, asynchronous=True,
                         fifo=msg_class == am.LONG)
        row_token = self.token if token is None else token
        self._fields.append(dict(
            type=t, nwords=nwords, dst_addr=dst_addr, handler=handler,
            token=row_token))
        self._lint_rows.append((msg_class, _lint.static_int(dst_addr),
                                nwords, _lint.static_int(handler),
                                _lint.static_int(row_token)))
        self._payloads.append(row)
        self._tx_words += nwords
        self.msgs_sent += 1
        if len(self._fields) >= self.watermark:
            state = self.flush(state)
        return state

    def send_signal(self, state: PgasState, *, handler=hd.H_ADD, arg=1,
                    token=None) -> PgasState:
        """Short-AM convenience: enqueue a signal/credit-return."""
        return self.send(state, None, msg_class=am.SHORT, handler=handler,
                         arg=arg, token=token)

    # -- flush -----------------------------------------------------------------

    def _stack_column(self, name):
        vals = [f[name] for f in self._fields]
        if all(_is_concrete(v) for v in vals):
            return jnp.asarray(np.asarray(vals, np.int32))
        return jnp.stack([jnp.asarray(v, jnp.int32) for v in vals])

    def _stack_payloads(self):
        if all(isinstance(r, np.ndarray) for r in self._payloads):
            return jnp.asarray(np.stack(self._payloads))
        return jnp.stack([jnp.asarray(r, self.dtype) for r in self._payloads])

    def flush(self, state: PgasState) -> PgasState:
        """Ship the pending stack as one collective and absorb it.

        No-op when nothing is pending.  On an acked transport the last
        row's async bit is cleared and its ack rides the *mailbox*
        token: exactly one credit per flush, however the stack mixed
        handler classes or per-message flags.
        """
        n = len(self._fields)
        if n == 0:
            return state
        acked = self.ctx.transport.acked
        w_ivs, grants = [], []
        for cls, addr, nw, h_s, tok in self._lint_rows:
            if cls == am.LONG and nw:
                w_ivs.append(_lint.Interval(addr, nw))
            elif (cls == am.SHORT and h_s == hd.H_ADD
                  and addr is not None and tok is not None):
                grants.append((tok, addr))   # Short rows: dst_addr = arg
        tag = _lint.emit(
            "mailbox_flush", self.pattern, writes=tuple(w_ivs),
            token=self.token, acked=acked,
            deferred_reply=self.reply_via is not None,
            credit_grants=tuple(grants), mailbox_id=id(self),
            segment_words=self.ctx.segment_words, detail={"rows": n})
        with _lint.scope(tag):
            cols = {name: self._stack_column(name) for name in _ROW_FIELDS}
            hdrs = am.encode_batch(
                n, src=self.ctx.my_id(),
                dst=ops._dst_of(self.ctx, self.pattern), **cols)
            if acked:
                # one ack per flush: only the final row requests a reply
                # (clear async BEFORE masking so non-senders stay all-NOP)
                hdrs = hdrs.at[n - 1, 0].set(hdrs[n - 1, 0] & ~am.FLAG_ASYNC)
            hdrs = ops._mask_nonparticipants(self.ctx, self.pattern, hdrs)
            pays = self._stack_payloads()
            state = gc.dataclasses_replace(
                state, tx_words=state.tx_words + jnp.where(
                    ops._is_sender(self.ctx, self.pattern),
                    self._tx_words, 0))
            hdr_r, pay_r = ops._exchange(self.ctx, self.pattern, hdrs, pays)
            state = gc.ingress_stack(self.ctx, state, hdr_r, pay_r,
                                     self.msg_words)
            if acked:
                # the ack is accounted on the mailbox token, not whatever
                # per-message token the final row happened to carry
                h_last = dataclasses.replace(
                    am.decode(hdr_r[n - 1]),
                    token=jnp.asarray(self.token, jnp.int32))
                state = ops._deliver_reply(self.ctx, state, self.pattern,
                                           h_last, token=self.token,
                                           reply_via=self.reply_via)
        self._fields.clear()
        self._payloads.clear()
        self._lint_rows.clear()
        self._tx_words = 0
        self.flushes += 1
        return state


class MultiMailbox:
    """One coalescing mailbox over SEVERAL destination patterns.

    A plain :class:`Mailbox` is bound to one pattern, so an actor phase
    spraying K neighbor links costs K flush collectives (plus K
    replies).  A MultiMailbox keeps one pending sub-stack per pattern
    and flushes them TOGETHER: patterns whose source and destination
    sets are disjoint (:func:`repro.core.ops.group_disjoint_patterns`)
    concatenate their stacks and cross the links as ONE ``ppermute``
    per group — the :func:`repro.core.ops.put_long_multi` wire plan
    applied to the actor layer — absorbed by the same mixed-class
    scanned ingress.

    Ack accounting on an acked transport: the last row of EACH
    pattern's sub-stack is acked and each group adds ONE counted reply
    collective returning every pattern's ack on the *mailbox* token —
    one credit per pattern per flush, one reply collective per group.
    ``wait_replies(token=mmb.token, n=<patterns flushed>)`` is the
    phase-boundary fence.
    """

    def __init__(self, ctx: ShoalContext, patterns, *, msg_words: int,
                 watermark: int = DEFAULT_WATERMARK, token: int = 0,
                 dtype=jnp.float32):
        self.patterns = [list(p) for p in patterns]
        if not self.patterns:
            raise ValueError("MultiMailbox needs at least one pattern")
        self.ctx = ctx
        self.token = int(token)
        self.msg_words = int(msg_words)
        self.watermark = int(watermark)
        # sub-box watermarks are disabled: the MultiMailbox watermark
        # governs the COMBINED pending count so flushes stay grouped
        self._boxes = [Mailbox(ctx, p, msg_words=msg_words,
                               watermark=1 << 30, token=token, dtype=dtype)
                       for p in self.patterns]
        self.groups = ops.group_disjoint_patterns(self.patterns)
        self.flushes = 0

    @property
    def pending(self) -> int:
        return sum(b.pending for b in self._boxes)

    @property
    def msgs_sent(self) -> int:
        return sum(b.msgs_sent for b in self._boxes)

    def send(self, state: PgasState, pattern_idx: int, payload=None,
             **kw) -> PgasState:
        """Append one tiny AM to pattern ``pattern_idx``'s sub-stack
        (same per-message kwargs as :meth:`Mailbox.send`)."""
        state = self._boxes[pattern_idx].send(state, payload, **kw)
        if self.pending >= self.watermark:
            state = self.flush(state)
        return state

    def flush(self, state: PgasState) -> PgasState:
        """Ship every pattern's pending sub-stack, one collective per
        disjoint-pattern group (plus, if acked, one counted reply per
        group).  No-op when nothing is pending anywhere."""
        if self.pending == 0:
            return state
        acked = self.ctx.transport.acked
        for grp in self.groups:
            boxes = [(i, self._boxes[i]) for i in grp
                     if self._boxes[i].pending]
            if not boxes:
                continue
            group_tag = None
            hdr_rows, pay_rows, union = [], [], []
            for _, box in boxes:
                n = box.pending
                w_ivs, grants = [], []
                for cls, addr, nw, h_s, tok in box._lint_rows:
                    if cls == am.LONG and nw:
                        w_ivs.append(_lint.Interval(addr, nw))
                    elif (cls == am.SHORT and h_s == hd.H_ADD
                          and addr is not None and tok is not None):
                        grants.append((tok, addr))
                tag = _lint.emit(
                    "mailbox_flush", box.pattern, writes=tuple(w_ivs),
                    token=self.token, acked=acked,
                    credit_grants=tuple(grants), mailbox_id=id(self),
                    segment_words=self.ctx.segment_words,
                    detail={"rows": n, "multi": True})
                group_tag = group_tag or tag
                union.extend((s, d) for s, d in box.pattern)
                with _lint.scope(tag):
                    cols = {name: box._stack_column(name)
                            for name in _ROW_FIELDS}
                    hdrs = am.encode_batch(
                        n, src=self.ctx.my_id(),
                        dst=ops._dst_of(self.ctx, box.pattern), **cols)
                    if acked:
                        # each pattern's final row is acked; the counted
                        # group reply returns one credit per pattern
                        hdrs = hdrs.at[n - 1, 0].set(
                            hdrs[n - 1, 0] & ~am.FLAG_ASYNC)
                    hdrs = ops._mask_nonparticipants(self.ctx, box.pattern,
                                                     hdrs)
                    hdr_rows.append(hdrs)
                    pay_rows.append(box._stack_payloads())
                    state = gc.dataclasses_replace(
                        state, tx_words=state.tx_words + jnp.where(
                            ops._is_sender(self.ctx, box.pattern),
                            box._tx_words, 0))
                box._fields.clear()
                box._payloads.clear()
                box._lint_rows.clear()
                box._tx_words = 0
                box.flushes += 1
            union = sorted(set(union))
            with _lint.scope(group_tag):
                hdr_r, pay_r = ops._exchange(
                    self.ctx, union, jnp.concatenate(hdr_rows, axis=0),
                    jnp.concatenate(pay_rows, axis=0))
                state = gc.ingress_stack(self.ctx, state, hdr_r, pay_r,
                                         self.msg_words)
                if acked:
                    # the ack lands on the mailbox token regardless of
                    # per-row tokens; any non-async non-NOP row counts
                    state = ops._counted_group_reply(
                        self.ctx, state, union, hdr_r,
                        token=self.token, classes=None)
        self.flushes += 1
        return state


class ReplyMailbox:
    """Deferred-ack aggregation: the reply side of the actor layer.

    Ops called with ``reply_via=this`` skip their immediate auto-reply
    collective; instead the mailbox records one owed credit per
    ``(pattern, token)``.  ``flush`` returns all owed credits for each
    key as ONE Short AM with ``H_ADD`` and ``arg=count`` along the
    reversed pattern — K acked puts to a destination cost one reply
    collective instead of K.  Counts are trace-time (the set of puts in
    a phase is static in SPMD dataflow), so the coalesced return lowers
    to a single constant-arg signal.
    """

    def __init__(self, ctx: ShoalContext):
        self.ctx = ctx
        self._owed: dict[tuple, int] = {}

    @property
    def pending(self) -> int:
        return sum(self._owed.values())

    def note(self, pattern, token) -> None:
        """Record one owed credit (called by the op layer).

        ``token`` must be static: the coalesced return is a single Short
        AM whose ``arg`` is the trace-time credit *count* per
        ``(pattern, token)`` key, so a traced token has no dict key to
        accumulate under.  Rather than let the caller hit JAX's generic
        concretization error deep inside ``int()``, raise a targeted
        one that names the fix.
        """
        try:
            key = (tuple(tuple(p) for p in pattern), int(token))
        except Exception:
            raise ValueError(
                "ReplyMailbox.note: reply_via coalescing needs a static "
                "(python int) token — owed credits are counted per "
                f"(pattern, token) at trace time, and this token is "
                f"{type(token).__name__!s} (a traced/non-concrete value "
                "has no trace-time key to accumulate under). Either pass "
                "a concrete token to the put op, or flush this reply "
                "mailbox first (state = reply_mailbox.flush(state)) and "
                "issue the traced-token op with reply_via=None so its "
                "ack ships immediately instead of coalescing.") from None
        self._owed[key] = self._owed.get(key, 0) + 1

    def flush(self, state: PgasState) -> PgasState:
        """Return every owed credit, one coalesced Short AM per
        (pattern, token): H_ADD with the count as the argument."""
        owed, self._owed = self._owed, {}
        for (pattern, token), count in owed.items():
            state = ops.put_short(
                self.ctx, state, ops._reverse(list(pattern)),
                handler=hd.H_ADD, arg=count, token=token, asynchronous=True)
        return state
