"""Host-side event mailbox (control-plane analogue of the device mailbox).

The serve engine emits a slot event per lane transition (acquire on
submit, release on completion).  Delivering each to a scheduler /
metrics sink one at a time is the same tiny-message anti-pattern the
device mailbox exists for, so :class:`EventMailbox` applies the same
contract host-side: events accumulate per mailbox and are delivered to
the sink in ONE batch per watermark hit or explicit phase-boundary
flush (the engine flushes once per decode step).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

DEFAULT_WATERMARK = 64


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """One serve-engine lane transition."""

    kind: str      # "acquire" | "release"
    lane: int
    rid: int       # request ID occupying / leaving the lane


class EventMailbox:
    """Watermark-buffered event delivery.

    ``send`` appends; the batch goes to ``sink`` (one call, whole list)
    when ``watermark`` events are pending or on ``flush``.  With no sink
    the flushed batch is simply returned — callers can poll.  Counters
    mirror the device mailbox: ``sent`` events in, ``flushes`` batches
    out.
    """

    def __init__(self, watermark: int = DEFAULT_WATERMARK,
                 sink: Callable[[Sequence[SlotEvent]], None] | None = None):
        if watermark < 1:
            raise ValueError("watermark must be >= 1")
        self.watermark = int(watermark)
        self.sink = sink
        self._pending: list[SlotEvent] = []
        self.sent = 0
        self.flushes = 0

    @property
    def pending(self) -> int:
        return len(self._pending)

    def send(self, event: SlotEvent) -> None:
        self._pending.append(event)
        self.sent += 1
        if len(self._pending) >= self.watermark:
            self.flush()

    def flush(self) -> list[SlotEvent]:
        """Deliver the pending batch (no-op when empty)."""
        if not self._pending:
            return []
        batch, self._pending = self._pending, []
        self.flushes += 1
        if self.sink is not None:
            self.sink(batch)
        return batch
