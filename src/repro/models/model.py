"""Model assembly: config -> init / train forward / prefill / decode.

Layers are grouped into homogeneous *segments* (a superblock pattern x a
repeat count) and each segment is ``lax.scan``-ed over its stacked
params, so a 100-layer model lowers to a compact HLO whose collectives
appear once per superblock (the dry-run collective parser multiplies by
the recorded trip counts).

Families map to superblock plans:
  dense        [("dense",) * 1] x L
  moe          [("dense",)] x first_k_dense + [("moe",)] x rest
  vlm          [4 x "dense" + "cross"] x (L / 5)
  hybrid       [("rglru","rglru","attn_local")] x (L // 3) + remainder
  ssm (xlstm)  [7 x "mlstm" + "slstm"] x (L / 8)
  audio        dense with LayerNorm/GELU and an embedding-stub frontend
"""

from __future__ import annotations

import dataclasses

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map

from repro.models import attention as attn
from repro.models import blocks as bl
from repro.models import moe as moe_lib
from repro.models import recurrent as rec
from repro.models import xlstm as xl


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0
    qkv_bias: bool = False
    norm: str = "rms"            # rms | ln
    mlp: str = "swiglu"          # swiglu | gelu
    rope_base: float = 10000.0
    tie_embeddings: bool = False
    # moe
    moe: moe_lib.MoEDims | None = None
    first_k_dense: int = 0
    # mla
    mla: attn.MLADims | None = None
    # vlm
    cross_every: int = 0
    n_image_tokens: int = 0
    # hybrid (recurrentgemma)
    block_pattern: tuple[str, ...] = ()
    window: int = 0
    lru_width: int = 0
    # xlstm
    slstm_every: int = 0
    mlstm_pf: float = 2.0
    mlstm_chunk: int = 64
    # frontend: tokens | embeddings (audio frame / stubbed modality)
    frontend: str = "tokens"
    # policy
    dtype: Any = jnp.bfloat16
    fsdp: bool = False
    tp: bool = True              # False: no tensor parallelism — weights
                                 # replicated (or FSDP), model axis joins DP
    seq_shard: bool = False      # shard SEQUENCE over the model axis and
                                 # use ring attention (long prefill mode;
                                 # requires tp=False, full attention)
    remat: str = "none"          # none | full | dots (activation ckpt policy)
    aux_loss_weight: float = 0.01
    sub_quadratic: bool = False  # may run long_500k

    @property
    def dh(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def dr(self) -> int:
        return self.lru_width or self.d_model

    def segments(self) -> list[tuple[tuple[str, ...], int]]:
        L = self.n_layers
        if self.family in ("dense", "audio"):
            return [(("dense",), L)]
        if self.family == "moe":
            segs = []
            if self.first_k_dense:
                segs.append((("dense",), self.first_k_dense))
            segs.append((("moe",), L - self.first_k_dense))
            return segs
        if self.family == "vlm":
            k = self.cross_every
            assert L % k == 0
            return [(("dense",) * (k - 1) + ("cross",), L // k)]
        if self.family == "hybrid":
            pat = self.block_pattern or ("rglru", "rglru", "attn_local")
            full, rem = divmod(L, len(pat))
            segs = [(pat, full)]
            if rem:
                segs.append((pat[:rem], 1))
            return segs
        if self.family == "ssm":
            k = self.slstm_every
            if k:
                assert L % k == 0
                return [(("mlstm",) * (k - 1) + ("slstm",), L // k)]
            return [(("mlstm",), L)]
        raise ValueError(self.family)

    def num_params(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# per-block init / apply / cache / specs
# --------------------------------------------------------------------------

def _init_norm(cfg, key):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def _norm(cfg, p, x):
    if cfg.norm == "ln":
        return bl.layer_norm(x, p["scale"], p["bias"])
    return bl.rms_norm(x, p["scale"])


def _init_mlp(cfg, key):
    if cfg.mlp == "gelu":
        ks = jax.random.split(key, 2)
        return {"wi": bl.dense_init(ks[0], (cfg.d_model, cfg.d_ff)),
                "bi": jnp.zeros((cfg.d_ff,), jnp.float32),
                "wo": bl.dense_init(ks[1], (cfg.d_ff, cfg.d_model)),
                "bo": jnp.zeros((cfg.d_model,), jnp.float32)}
    ks = jax.random.split(key, 3)
    return {"wg": bl.dense_init(ks[0], (cfg.d_model, cfg.d_ff)),
            "wu": bl.dense_init(ks[1], (cfg.d_model, cfg.d_ff)),
            "wd": bl.dense_init(ks[2], (cfg.d_ff, cfg.d_model))}


def _mlp(cfg, p, x):
    if cfg.mlp == "gelu":
        return bl.gelu_mlp(x, p["wi"], p["bi"], p["wo"], p["bo"])
    return bl.swiglu(x, p["wg"], p["wu"], p["wd"])


def _init_block(cfg, kind: str, key):
    ks = jax.random.split(key, 4)
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    if kind == "dense":
        a = (attn.init_mla(ks[0], d, H, cfg.mla) if cfg.mla
             else attn.init_gqa(ks[0], d, H, K, dh, cfg.qkv_bias))
        return {"ln1": _init_norm(cfg, ks[1]), "attn": a,
                "ln2": _init_norm(cfg, ks[2]), "mlp": _init_mlp(cfg, ks[3])}
    if kind == "moe":
        a = (attn.init_mla(ks[0], d, H, cfg.mla) if cfg.mla
             else attn.init_gqa(ks[0], d, H, K, dh, cfg.qkv_bias))
        return {"ln1": _init_norm(cfg, ks[1]), "attn": a,
                "ln2": _init_norm(cfg, ks[2]),
                "moe": moe_lib.init_moe(ks[3], d, cfg.moe)}
    if kind == "cross":
        return {"ln1": _init_norm(cfg, ks[1]),
                "xattn": attn.init_cross(ks[0], d, H, K, dh),
                "ln2": _init_norm(cfg, ks[2]), "mlp": _init_mlp(cfg, ks[3])}
    if kind == "attn_local":
        return {"ln1": _init_norm(cfg, ks[1]),
                "attn": attn.init_gqa(ks[0], d, H, K, dh, cfg.qkv_bias),
                "ln2": _init_norm(cfg, ks[2]), "mlp": _init_mlp(cfg, ks[3])}
    if kind == "rglru":
        return {"ln1": _init_norm(cfg, ks[1]),
                "rnn": rec.init_rglru(ks[0], d, cfg.dr, cfg.n_heads),
                "ln2": _init_norm(cfg, ks[2]), "mlp": _init_mlp(cfg, ks[3])}
    if kind == "mlstm":
        return {"cell": xl.init_mlstm(ks[0], d, cfg.n_heads, cfg.mlstm_pf)}
    if kind == "slstm":
        return {"cell": xl.init_slstm(ks[0], d, cfg.n_heads)}
    raise ValueError(kind)


def _block_cache(cfg, kind: str, B: int, slots: int):
    K, dh = cfg.n_kv_heads, cfg.dh
    if kind in ("dense", "moe"):
        if cfg.mla:
            return attn.make_mla_cache(B, slots, cfg.mla, cfg.dtype)
        return attn.make_kv_cache(B, slots, K, dh, cfg.dtype)
    if kind == "attn_local":
        return attn.make_kv_cache(B, min(slots, cfg.window), K, dh, cfg.dtype)
    if kind == "rglru":
        return rec.make_rglru_state(B, cfg.dr)
    if kind == "mlstm":
        return xl.make_mlstm_state(B, cfg.d_model, cfg.n_heads, cfg.mlstm_pf)
    if kind == "slstm":
        return xl.make_slstm_state(B, cfg.d_model)
    if kind == "cross":
        return {}   # image kv is recomputed from the (static) image feats
    raise ValueError(kind)


def _apply_block(cfg, kind: str, p, x, positions, *, cache=None,
                 image_feats=None, ep_ctx=None, ring_ctx=None):
    """Returns (x, new_cache, aux)."""
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "attn_local"):
        window = cfg.window if kind == "attn_local" else 0
        h = _norm(cfg, p["ln1"], x)
        if cfg.mla and kind != "attn_local":
            a, cache = attn.mla(p["attn"], h, positions, H=cfg.n_heads,
                                dims=cfg.mla, cache=cache)
        else:
            a, cache = attn.gqa(p["attn"], h, positions, H=H, K=K, dh=dh,
                                window=window, rope_base=cfg.rope_base,
                                cache=cache,
                                ring_ctx=None if window else ring_ctx)
        x = x + a
        h = _norm(cfg, p["ln2"], x)
        if kind == "moe":
            if ep_ctx is not None:
                f, aux = ep_ctx(p["moe"], h)
                if cfg.moe.n_shared:   # shared experts: dense, GSPMD-sharded
                    B_, S_, d_ = h.shape
                    hf = h.reshape(B_ * S_, d_)
                    f = f + bl.swiglu(hf, p["moe"]["ws_g"], p["moe"]["ws_u"],
                                      p["moe"]["ws_d"]).reshape(B_, S_, d_)
            else:
                f, aux = moe_lib.moe_ffn(p["moe"], h, cfg.moe)
            x = x + f
        else:
            x = x + _mlp(cfg, p["mlp"], h)
        return x, cache, aux
    if kind == "cross":
        h = _norm(cfg, p["ln1"], x)
        x = x + attn.cross_attention(p["xattn"], h, image_feats, H=H, K=K, dh=dh)
        h = _norm(cfg, p["ln2"], x)
        x = x + _mlp(cfg, p["mlp"], h)
        return x, cache, aux
    if kind == "rglru":
        h = _norm(cfg, p["ln1"], x)
        r, cache = rec.rglru_block(p["rnn"], h, state=cache)
        x = x + r
        h = _norm(cfg, p["ln2"], x)
        x = x + _mlp(cfg, p["mlp"], h)
        return x, cache, aux
    if kind == "mlstm":
        x, cache = xl.mlstm_block(p["cell"], x, nh=cfg.n_heads,
                                  chunk=cfg.mlstm_chunk, state=cache)
        return x, cache, aux
    if kind == "slstm":
        x, cache = xl.slstm_block(p["cell"], x, nh=cfg.n_heads, state=cache)
        return x, cache, aux
    raise ValueError(kind)


# --------------------------------------------------------------------------
# the Model
# --------------------------------------------------------------------------

class Model:
    """Functional model: explicit params, no framework magic.

    ``mesh``/``axis_rules`` enable (a) the MoE expert-parallel shard_map
    island and (b) activation sharding constraints; both off for pure
    single-device use (smoke tests, oracles).
    """

    def __init__(self, cfg: ModelConfig, mesh=None,
                 dp_axes: tuple[str, ...] = ("data",),
                 model_axis: str = "model"):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.model_axis = model_axis
        self.segs = cfg.segments()

    # -- init ---------------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.segs) + 3)
        params: dict[str, Any] = {}
        params["embed"] = bl.embed_init(keys[0], (cfg.vocab, cfg.d_model))
        params["final_norm"] = _init_norm(cfg, keys[1])
        if not cfg.tie_embeddings:
            params["lm_head"] = bl.dense_init(keys[2], (cfg.d_model, cfg.vocab))
        params["segments"] = []
        for si, (pat, reps) in enumerate(self.segs):
            sk = jax.random.split(keys[3 + si], reps)

            def init_one(k):
                kk = jax.random.split(k, len(pat))
                return {f"b{i}_{kind}": _init_block(cfg, kind, kk[i])
                        for i, kind in enumerate(pat)}

            stacked = jax.vmap(init_one)(sk)
            params["segments"].append(stacked)
        return params

    # -- sharding specs -------------------------------------------------------

    def param_pspecs(self, params) -> Any:
        """PartitionSpec tree matching ``params`` (logical rules -> mesh)."""
        cfg = self.cfg
        fsdp = self.dp_axes[-1] if cfg.fsdp else None
        m = self.model_axis if cfg.tp else None

        def spec_for(path, leaf) -> P:
            names = [getattr(k, "key", str(k)) for k in path]
            name = names[-1]
            parent = names[-2] if len(names) >= 2 else ""
            stacked = "segments" in names
            if name == "embed":
                s = P(m, None)
            elif name == "lm_head":
                s = P(fsdp, m)
            elif parent == "rnn" and name in ("wr", "wi"):
                s = P(m, None, None)             # block-diag RG-LRU gates
            elif name in ("wq", "wk", "wv", "wg", "wu", "wi", "w_up",
                          "w_gate", "wx", "wy"):
                if parent == "moe":           # stacked experts (E, d, fe)
                    s = P(m, fsdp, None)
                else:
                    s = P(fsdp, m)
            elif name in ("wuq", "wuk", "wuv"):
                s = P(None, m)
            elif name in ("wdq", "wdkv"):
                s = P(fsdp, None)
            elif name in ("wo", "wd", "w_down", "ws_d"):
                if parent == "moe":           # (E, fe, d)
                    s = P(m, None, fsdp)
                else:
                    s = P(m, fsdp)
            elif name in ("ws_g", "ws_u"):
                s = P(fsdp, m)
            elif name in ("wr",) and leaf.ndim >= 3:
                s = P(m, None, None)             # block-diag gates
            elif name == "r":
                s = P(m, None, None)             # slstm block-diag recurrence
            elif name == "conv":
                s = P(None, m)
            elif name in ("bq", "bk", "bv", "bi"):
                s = P(m)
            elif name == "w" and leaf.ndim == 2:
                s = P(fsdp, m)                   # slstm gate proj
            elif name == "router":
                s = P(None, None)
            else:
                s = P(*([None] * leaf.ndim))
            if stacked:                           # leading scan dim
                s = P(None, *tuple(s))
            # pad/truncate to leaf rank
            t = tuple(s)
            if len(t) < leaf.ndim:
                t = t + (None,) * (leaf.ndim - len(t))
            return self._sanitize(P(*t[:leaf.ndim]), leaf.shape)

        return jax.tree_util.tree_map_with_path(spec_for, params)

    def _sanitize(self, spec: P, shape) -> P:
        """Drop mesh axes from dims they do not divide (e.g. 10 RG-LRU
        gate blocks over a 16-way model axis) — replicate those instead."""
        if self.mesh is None:
            return spec
        t = list(spec)
        for i, s in enumerate(t):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            sz = 1
            for a in axes:
                sz *= self.mesh.shape[a]
            if shape[i] % sz:
                t[i] = None
        return P(*t)

    def _constrain(self, x, spec):
        if self.mesh is None:
            return x
        # Inside a fully-manual shard_map region (old-jax compat path)
        # sharding hints over the manual axes are illegal and
        # meaningless — the data is already placed.  Skip them there.
        from repro.runtime.jax_compat import bound_axis_names
        bound = bound_axis_names()
        if bound:
            def touches_bound(a):
                axes = a if isinstance(a, tuple) else (a,)
                return any(x in bound for x in axes)
            if any(a is not None and touches_bound(a) for a in spec):
                return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def _ep_ctx(self):
        """The expert-parallel shard_map island (or None).

        FULLY manual over every mesh axis (partial-manual nesting trips
        XLA partitioner bugs at 3-D meshes): tokens split over the DP
        axes, expert slabs over model (+FSDP over data), combine psum'ed
        inside.  Boundaries are f32 so autodiff-inserted collectives are
        f32 (see moe.moe_routed_island).  Shared experts / aux weighting
        happen outside in plain GSPMD code (_apply_block).
        """
        cfg = self.cfg
        if (self.mesh is None or cfg.moe is None or not cfg.tp
                or self.mesh.shape[self.model_axis] == 1):
            return None
        msize = self.mesh.shape[self.model_axis]
        if cfg.moe.n_experts % msize:
            return None                           # not EP-shardable; dense TP

        m = self.model_axis
        fsdp = self.dp_axes[-1] if cfg.fsdp else None
        all_axes = tuple(self.mesh.axis_names)
        routed_spec = {
            "router": P(None, None),
            "wg": P(m, fsdp, None), "wu": P(m, fsdp, None),
            "wd": P(m, None, fsdp),
        }

        def island(p, h32):
            return moe_lib.moe_routed_island(
                p, h32, cfg.moe, model_axis=m, all_axes=all_axes,
                fsdp_axis=fsdp, compute_dtype=cfg.dtype)

        # a2a/rs dispatch want tokens sequence-sharded over the model axis
        # at the island boundary; psum wants them replicated over it.
        seq = m if cfg.moe.dispatch in ("a2a", "rs") else None
        smapped = shard_map(
            island, mesh=self.mesh,
            in_specs=(routed_spec, P(self.dp_axes, seq, None)),
            out_specs=(P(self.dp_axes, seq, None), P()),
            check_vma=False)

        def run(p_moe, h):
            routed = {k: p_moe[k] for k in ("router", "wg", "wu", "wd")}
            out32, aux = smapped(routed, h.astype(jnp.float32))
            return out32.astype(h.dtype), aux

        return run

    # -- forward -------------------------------------------------------------

    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "embeddings":
            x = batch["embeddings"].astype(cfg.dtype)
        else:
            x = params["embed"].astype(cfg.dtype)[batch["tokens"]]
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        return x

    def _unembed(self, params, x):
        cfg = self.cfg
        x = _norm(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].astype(x.dtype).T
        else:
            logits = x @ params["lm_head"].astype(x.dtype)
        vocab_axis = self.model_axis if cfg.tp else None
        if vocab_axis in self.dp_axes or cfg.seq_shard:
            vocab_axis = None   # the model axis carries S (or DP) instead
        return self._constrain(
            logits, P(self.dp_axes, self._seq_axis(), vocab_axis))

    def _seq_axis(self):
        """The axis activations' S dim is sharded over (seq_shard mode)."""
        if self.cfg.seq_shard and self.mesh is not None:
            return self.model_axis
        return None

    def _ring_ctx(self):
        """Ring attention: only in the no-TP sequence-parallel mode.
        With TP + seq_shard (Megatron-SP), attention instead runs
        head-sharded with GSPMD-inserted bf16 all-gather/reduce-scatter
        around it — the sequence axis exists for the norms/MLP/MoE."""
        cfg = self.cfg
        if not cfg.seq_shard or cfg.tp or self.mesh is None:
            return None
        if self.mesh.shape[self.model_axis] == 1:
            return None
        return (self.mesh, self.model_axis, self.dp_axes)

    def _run_segments(self, params, x, positions, *, caches=None,
                      image_feats=None):
        """Scan each segment; returns (x, new_caches, aux_total)."""
        cfg = self.cfg
        ep_ctx = self._ep_ctx()
        ring_ctx = self._ring_ctx() if x.shape[1] > 1 else None
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for si, (pat, reps) in enumerate(self.segs):
            seg_params = params["segments"][si]
            seg_cache = None if caches is None else caches[si]

            def superblock(x, layer):
                p_layer, c_layer = layer
                aux_sb = jnp.zeros((), jnp.float32)
                c_out = {}
                for i, kind in enumerate(pat):
                    key = f"b{i}_{kind}"
                    c_in = None if c_layer is None else c_layer.get(key)
                    x2, c2, aux = _apply_block(
                        cfg, kind, p_layer[key], x, positions, cache=c_in,
                        image_feats=image_feats, ep_ctx=ep_ctx,
                        ring_ctx=ring_ctx)
                    x = self._constrain(
                        x2, P(self.dp_axes, self._seq_axis(), None))
                    c_out[key] = c2 if c2 is not None else {}
                    aux_sb = aux_sb + aux
                return x, (c_out, aux_sb)

            if seg_cache is None:
                def body(x, p_layer):
                    x, (_, aux_sb) = superblock(x, (p_layer, None))
                    return x, aux_sb

                if cfg.remat == "full":
                    body = jax.checkpoint(body)
                elif cfg.remat == "dots":
                    body = jax.checkpoint(
                        body, policy=jax.checkpoint_policies
                        .dots_with_no_batch_dims_saveable)
                x, auxs = jax.lax.scan(body, x, seg_params)
                new_caches.append(None)
                aux_total = aux_total + jnp.sum(auxs)
            else:
                def body_c(x, layer):
                    x, (c_out, aux_sb) = superblock(x, layer)
                    return x, (c_out, aux_sb)

                x, (c_new, auxs) = jax.lax.scan(body_c, x,
                                                (seg_params, seg_cache))
                new_caches.append(c_new)
                aux_total = aux_total + jnp.sum(auxs)
        return x, new_caches, aux_total

    def forward_train(self, params, batch):
        """batch: tokens/embeddings (+labels, +image_feats) -> (logits, aux)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        x = self._constrain(x, P(self.dp_axes, self._seq_axis(), None))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        image_feats = batch.get("image_feats")
        x, _, aux = self._run_segments(params, x, positions,
                                       image_feats=image_feats)
        return self._unembed(params, x), aux

    def loss(self, params, batch):
        logits, aux = self.forward_train(params, batch)
        ce = bl.softmax_xent(logits, batch["labels"])
        return ce + self.cfg.aux_loss_weight * aux

    # -- serving -------------------------------------------------------------

    def make_cache(self, B: int, slots: int):
        caches = []
        for pat, reps in self.segs:
            one = {f"b{i}_{kind}": _block_cache(self.cfg, kind, B, slots)
                   for i, kind in enumerate(pat)}
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one)
            caches.append(stacked)
        return caches

    def prefill(self, params, batch, cache):
        """Run the prompt through the model, filling the cache.

        Returns (logits_last (B, vocab), new_cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        x = self._constrain(x, P(self.dp_axes, self._seq_axis(), None))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, cache, _ = self._run_segments(params, x, positions, caches=cache,
                                         image_feats=batch.get("image_feats"))
        logits = self._unembed(params, x[:, -1:])
        return logits[:, 0], cache

    def decode_step(self, params, cache, token, pos, image_feats=None):
        """One decode step. token: (B, 1) ids (or (B,1,d) embeddings);
        pos: (B,) absolute positions.  VLM decode re-attends the static
        ``image_feats``.  Returns (logits (B, vocab), cache)."""
        cfg = self.cfg
        if cfg.frontend == "embeddings":
            x = token.astype(cfg.dtype)
        else:
            x = params["embed"].astype(cfg.dtype)[token]
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        positions = pos[:, None].astype(jnp.int32)
        x, cache, _ = self._run_segments(params, x, positions, caches=cache,
                                         image_feats=image_feats)
        logits = self._unembed(params, x)
        return logits[:, 0], cache


def build_model(cfg: ModelConfig, mesh=None,
                dp_axes: tuple[str, ...] = ("data",)) -> Model:
    return Model(cfg, mesh=mesh, dp_axes=dp_axes)
