"""Mixture-of-experts with expert parallelism (dbrx, deepseek-v2).

Routing is top-k softmax gating; experts are SwiGLU MLPs with stacked
weights (E, d, d_ff_e).  Dispatch is *sort-based* (argsort tokens by
expert, gather up to a static capacity per expert, expert einsum,
scatter-combine) so compiled FLOPs reflect only *active* expert compute
— a dense one-hot dispatch would inflate the roofline's compute term by
E/top_k.

Expert parallelism is a manual ``shard_map`` island inside the otherwise
GSPMD-sharded model (DESIGN.md Sec. 4): experts are sharded over the
``model`` axis, tokens are replicated across it within each data shard;
each device gathers tokens routed to *its* experts locally and the
combine is a single psum over the model axis — the Shoal Vectored-AM
pattern specialized to "dispatch local, combine collective".  The pure
single-device path (mesh=None) is the smoke-test/reference oracle.
"""

from __future__ import annotations

import dataclasses

import jax
from jax import lax
import jax.numpy as jnp

from repro.actors.coalesce import pack_meta_lane, unpack_meta_lane
from repro.models import blocks as bl


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # deepseek-v2 shared experts
    capacity_factor: float = 1.25
    router_norm: bool = True     # normalize top-k gate weights to sum 1
    dispatch: str = "psum"       # psum | a2a | rs  (EP combine strategy)
                                 # rs: tokens S-sharded at the boundary;
                                 # bf16 all-gather in, f32 reduce-scatter
                                 # out (half the psum wire bytes)


def init_moe(key, d, dims: MoEDims):
    ks = jax.random.split(key, 5)
    E, fe = dims.n_experts, dims.d_ff_expert
    p = {
        "router": bl.dense_init(ks[0], (d, E)),
        "wg": bl.dense_init(ks[1], (E, d, fe), in_axis=1),
        "wu": bl.dense_init(ks[2], (E, d, fe), in_axis=1),
        "wd": bl.dense_init(ks[3], (E, fe, d), in_axis=1),
    }
    if dims.n_shared:
        fs = dims.d_ff_expert * dims.n_shared
        ks2 = jax.random.split(ks[4], 3)
        p["ws_g"] = bl.dense_init(ks2[0], (d, fs))
        p["ws_u"] = bl.dense_init(ks2[1], (d, fs))
        p["ws_d"] = bl.dense_init(ks2[2], (fs, d))
    return p


def _route(router_w, x, dims: MoEDims):
    """Top-k gating. x: (T, d) -> (gates (T, k), experts (T, k), aux_loss)."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, dims.top_k)
    if dims.router_norm:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss
    T = x.shape[0]
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((dims.n_experts,), jnp.float32)
    ce = ce.at[experts.reshape(-1)].add(1.0) / (T * dims.top_k)
    aux = dims.n_experts * jnp.sum(me * ce)
    return gates.astype(x.dtype), experts, aux


def _expert_compute(p, x_e):
    """x_e: (E_local, C, d) -> (E_local, C, d) via per-expert SwiGLU."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, p["wg"].astype(x_e.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x_e, p["wu"].astype(x_e.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, p["wd"].astype(x_e.dtype))


def moe_ffn(p, x, dims: MoEDims):
    """Single-device reference MoE feed-forward over x (B, S, d) — the
    oracle the EP island (:func:`moe_routed_island`) is tested against.
    Includes the shared experts."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    E = dims.n_experts
    capacity = max(1, int(T * dims.top_k * dims.capacity_factor / E))
    out, aux = _dispatch_local(p, xf, dims, 0, E, capacity)
    if dims.n_shared:
        out = out + bl.swiglu(xf, p["ws_g"], p["ws_u"], p["ws_d"])
    return out.reshape(B, S, d), aux


def moe_routed_island(p_slab, x32, dims: MoEDims, *, model_axis: str,
                      all_axes: tuple, fsdp_axis: str | None,
                      compute_dtype):
    """Expert-parallel routed-experts body (runs FULLY MANUAL inside
    shard_map over every mesh axis).

    Per device: tokens = this DP shard's (B_loc, S, d); experts = slab
    [shard*E_local, ...) of the ``model`` axis, FSDP-sharded on the d/fe
    dim over ``fsdp_axis``.  Steps:

      1. all-gather the expert slab over the FSDP axis (the explicit
         ZeRO-3 weight gather; bf16 on the wire),
      2. sort-based local dispatch for local experts on local tokens,
      3. psum the combine over the model axis (f32 on the wire: bf16
         all-reduce trips an XLA-CPU ChangeOpDataType crash, and f32
         accumulation is standard practice anyway).

    The island boundary is f32 (``x32``) so every autodiff-inserted
    collective (the dx psum over ``model``) is f32 too.  Shared experts
    and the dense path live OUTSIDE (plain GSPMD code in model.py).
    """
    B, S, d = x32.shape
    xf = x32.astype(compute_dtype).reshape(B * S, d)

    def gather(w, dim):
        w = w.astype(compute_dtype)
        if fsdp_axis is None:
            return w
        return jax.lax.all_gather(w, fsdp_axis, axis=dim, tiled=True)

    p_local = {
        "router": p_slab["router"].astype(compute_dtype),
        "wg": gather(p_slab["wg"], 1),
        "wu": gather(p_slab["wu"], 1),
        "wd": gather(p_slab["wd"], 2),
    }
    E_local = p_local["wg"].shape[0]
    shard = jax.lax.axis_index(model_axis)
    T = xf.shape[0]
    if dims.dispatch == "a2a":
        n_shards = dims.n_experts // E_local
        out, aux = _dispatch_a2a(p_local, xf, dims, shard, E_local,
                                 n_shards, model_axis)
        out = out.astype(jnp.float32)
    elif dims.dispatch == "rs":
        # tokens arrive SEQUENCE-sharded: gather them (bf16 wire), run the
        # local-expert dispatch over the full token set, and hand back only
        # this shard's token slice via reduce-scatter (f32) — half the
        # all-reduce bytes, and both boundaries match the S-sharded
        # residual stream (no reshard at entry/exit).
        n_shards = dims.n_experts // E_local
        x_full = jax.lax.all_gather(xf, model_axis, axis=0, tiled=True)
        T_full = x_full.shape[0]
        capacity = max(1, int(T_full * dims.top_k * dims.capacity_factor
                              / dims.n_experts))
        out, aux = _dispatch_local(p_local, x_full, dims, shard * E_local,
                                   E_local, capacity)
        out = jax.lax.psum_scatter(out.astype(jnp.float32), model_axis,
                                   scatter_dimension=0, tiled=True)
    else:
        capacity = max(1, int(T * dims.top_k * dims.capacity_factor
                              / dims.n_experts))
        out, aux = _dispatch_local(p_local, xf, dims, shard * E_local,
                                   E_local, capacity)
        out = jax.lax.psum(out.astype(jnp.float32), model_axis)
    aux = jax.lax.pmean(aux, all_axes)
    return out.reshape(B, S, d), aux


def _dispatch_a2a(p_local, x, dims: MoEDims, shard, E_local: int,
                  n_shards: int, model_axis: str):
    """Vectored-AM EP: route local tokens, all-to-all them to their
    experts' owner shards, compute, all-to-all results back, combine.

    This is the paper's Vectored Long AM pattern on ICI (DESIGN.md): one
    hardware all-to-all scatters every token block to its remote
    address.  Tokens here are SEQUENCE-sharded over the model axis (the
    island boundary reshards), so wire bytes scale with T_local*top_k*d
    in bf16 instead of T_replicated*d in f32 psum.

    Static shapes: per-destination bucket capacity
    C = ceil(T * top_k * cf / n_shards); overflowing pairs are dropped
    (standard capacity semantics).
    """
    T, d = x.shape
    gates, experts, aux = _route(p_local["router"], x, dims)
    k = dims.top_k
    flat_e = experts.reshape(-1)                    # (T*k,)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    dest = flat_e // E_local                        # owner shard per pair

    C = max(1, int(-(-T * k * dims.capacity_factor // n_shards)))
    # rank of each pair within its destination bucket
    order = jnp.argsort(dest, stable=True)
    sorted_d = dest[order]
    idx = jnp.arange(sorted_d.size)
    first = jnp.searchsorted(sorted_d, jnp.arange(n_shards))
    rank_sorted = idx - first[sorted_d]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    ok = rank < C
    slot = jnp.where(ok, dest * C + rank, n_shards * C)

    send_x = jnp.zeros((n_shards * C + 1, d), x.dtype)
    send_x = send_x.at[slot].set(jnp.where(ok[:, None], x[flat_tok], 0))
    send_e = jnp.zeros((n_shards * C + 1,), jnp.int32)
    send_e = send_e.at[slot].set(jnp.where(ok, flat_e + 1, 0))  # 0 = empty

    # ship buckets to their owners (the vectored AM / hardware a2a).
    # The expert-id sideband rides INSIDE the token collective as one
    # extra bitcast lane (actor-layer metadata coalescing) — one
    # all_to_all for tokens+routing instead of one per section, and
    # bit-exact where a value cast to bf16 would corrupt ids > 256.
    meta = pack_meta_lane(send_e[:-1], x.dtype)
    send = jnp.concatenate([send_x[:-1], meta[:, None]], axis=1)
    r = lax.all_to_all(send.reshape(n_shards, C, d + 1), model_axis,
                       split_axis=0, concat_axis=0, tiled=False)
    r = r.reshape(n_shards * C, d + 1)
    rx = r[:, :d]
    re = unpack_meta_lane(r[:, d])

    # local second-stage dispatch: received rows -> local expert slots
    valid = re > 0
    le = jnp.clip(re - 1 - shard * E_local, 0, E_local - 1)
    C2 = max(1, int(-(-n_shards * C // E_local)))
    order2 = jnp.argsort(jnp.where(valid, le, E_local), stable=True)
    sorted_le = jnp.where(valid, le, E_local)[order2]
    idx2 = jnp.arange(sorted_le.size)
    first2 = jnp.searchsorted(sorted_le, jnp.arange(E_local + 1))
    rank2_sorted = idx2 - first2[sorted_le]
    rank2 = jnp.zeros_like(rank2_sorted).at[order2].set(rank2_sorted)
    ok2 = valid & (rank2 < C2)
    slot2 = jnp.where(ok2, le * C2 + rank2, E_local * C2)

    x_slots = jnp.zeros((E_local * C2 + 1, d), x.dtype)
    x_slots = x_slots.at[slot2].set(jnp.where(ok2[:, None], rx, 0))
    y_e = _expert_compute(p_local, x_slots[:-1].reshape(E_local, C2, d))
    y_rows = jnp.where(
        ok2[:, None],
        y_e.reshape(E_local * C2, d)[jnp.clip(slot2, 0, E_local * C2 - 1)], 0)

    # results travel home (reverse vectored AM)
    ry = lax.all_to_all(y_rows.reshape(n_shards, C, d), model_axis,
                        split_axis=0, concat_axis=0, tiled=False)
    ry = ry.reshape(n_shards * C, d)
    back = jnp.where(ok[:, None],
                     ry[jnp.clip(slot, 0, n_shards * C - 1)], 0)
    out = jnp.zeros((T, d), x.dtype).at[flat_tok].add(back * flat_g[:, None])
    return out, aux


def _dispatch_local(p_local, x, dims: MoEDims, e_lo, E_local: int,
                    capacity: int):
    """Sort-based dispatch for the E_local experts starting at ``e_lo``
    (may be traced) whose weights are pre-sliced in ``p_local``.  Tokens
    routed elsewhere contribute zero here (combined by the caller)."""
    T, d = x.shape
    gates, experts, aux = _route(p_local["router"], x, dims)
    flat_e = experts.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), dims.top_k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(sorted_e.size)
    first = jnp.searchsorted(sorted_e, jnp.arange(dims.n_experts))
    rank_sorted = idx - first[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)

    local = (flat_e >= e_lo) & (flat_e < e_lo + E_local) & (rank < capacity)
    slot = jnp.where(local, (flat_e - e_lo) * capacity + rank, E_local * capacity)
    x_slots = jnp.zeros((E_local * capacity + 1, d), x.dtype)
    x_slots = x_slots.at[slot].set(jnp.where(local[:, None], x[flat_tok], 0))
    x_e = x_slots[:-1].reshape(E_local, capacity, d)
    y_e = _expert_compute(p_local, x_e)
    y_slots = y_e.reshape(E_local * capacity, d)
    contrib = jnp.where(local[:, None],
                        y_slots[jnp.clip(slot, 0, E_local * capacity - 1)], 0)
    out = jnp.zeros((T, d), x.dtype).at[flat_tok].add(contrib * flat_g[:, None])
    return out, aux
