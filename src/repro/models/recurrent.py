"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The temporal-mixing block is: RMSNorm -> two branches
  gate branch:      linear (d -> dr) -> GeLU
  recurrent branch: linear (d -> dr) -> causal conv1d(width 4) -> RG-LRU
-> elementwise product -> output linear (dr -> d).

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
  a_t = exp(-c * softplus(L) * r_t)           (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the linear recurrence with an associative
scan (log-depth, sequence-parallelizable — why this family runs the
``long_500k`` shape); decode carries h as O(dr) state per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as bl

_C = 8.0


def init_rglru(key, d, dr, nb: int, conv_width: int = 4):
    """``nb``: gate blocks (block-diagonal gate projections, as in the
    reference RecurrentGemma implementation — also what makes the gates
    tensor-parallel: blocks shard like heads)."""
    ks = jax.random.split(key, 7)
    drb = dr // nb
    # Lambda parametrized so a = exp(-c*softplus(L)) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(-jnp.log(
        jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)) / _C))
    return {
        "wx": bl.dense_init(ks[1], (d, dr)),       # recurrent branch in
        "wy": bl.dense_init(ks[2], (d, dr)),       # gate branch in
        "conv": bl.dense_init(ks[3], (conv_width, dr)) * 0.1,
        "wr": bl.dense_init(ks[4], (nb, drb, drb), in_axis=1),  # recurrence gate
        "wi": bl.dense_init(ks[5], (nb, drb, drb), in_axis=1),  # input gate
        "lam": lam,
        "wo": bl.dense_init(ks[6], (dr, d)),
    }


def _block_diag(x, w):
    """x: (B,S,dr) @ block-diagonal w: (nb,drb,drb) -> (B,S,dr)."""
    B, S, dr = x.shape
    nb, drb, _ = w.shape
    xb = x.reshape(B, S, nb, drb)
    return jnp.einsum("bsnd,nde->bsne", xb, w.astype(x.dtype)).reshape(B, S, dr)


def _conv1d_causal(x, w, state=None):
    """Causal depthwise conv along S. x: (B,S,dr), w: (W,dr).

    ``state``: (B, W-1, dr) trailing context for decode; returns
    (out, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1):]
    return out, new_state


def _lru_scan(a, bx):
    """h_t = a_t h_{t-1} + b_t via associative scan over affine maps."""

    def combine(l, r):
        al, bl_ = l
        ar, br = r
        return al * ar, br + ar * bl_

    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return b_c


def rglru_block(p, x, *, state=None):
    """x: (B,S,d).  ``state``: None (training) or dict with h (B,dr) and
    conv (B,W-1,dr) for decode.  Returns (out, new_state)."""
    xr = x @ p["wx"].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["wy"].astype(x.dtype))
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _conv1d_causal(xr, p["conv"], conv_state)

    r = jax.nn.sigmoid(_block_diag(xc, p["wr"])).astype(jnp.float32)
    i = jax.nn.sigmoid(_block_diag(xc, p["wi"])).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r             # (B,S,dr)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12)) * (
        i * xc.astype(jnp.float32))

    if state is None:
        h = _lru_scan(a, gated)
        new_h = h[:, -1]
    else:
        h0 = state["h"].astype(jnp.float32)
        if x.shape[1] == 1:
            h = a * h0[:, None] + gated
            new_h = h[:, -1]
        else:  # chunked prefill with carried state
            h = _lru_scan(a, gated)
            # correct the scan with the carried initial state
            a_c = jnp.exp(jnp.cumsum(log_a, axis=1))
            h = h + a_c * h0[:, None]
            new_h = h[:, -1]

    out = (h.astype(x.dtype) * gate) @ p["wo"].astype(x.dtype)
    new_state = {"h": new_h, "conv": new_conv}
    return out, new_state


def make_rglru_state(B, dr, conv_width: int = 4, dtype=jnp.float32):
    return {
        "h": jnp.zeros((B, dr), dtype),
        "conv": jnp.zeros((B, conv_width - 1, dr), dtype),
    }
