"""Shared neural blocks: norms, MLPs, rotary embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# initializers (params are created in float32; compute casts per policy)
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis] if shape else 1
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, jnp.float32) * scale


def embed_init(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.02


def zeros_init(_key, shape):
    return jnp.zeros(shape, jnp.float32)


def ones_init(_key, shape):
    return jnp.ones(shape, jnp.float32)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x, wg, wu, wd):
    """SwiGLU gated MLP (llama/qwen/deepseek family)."""
    g = jax.nn.silu(x @ wg.astype(x.dtype))
    u = x @ wu.astype(x.dtype)
    return (g * u) @ wd.astype(x.dtype)


def gelu_mlp(x, wi, bi, wo, bo):
    """Plain GELU MLP (musicgen family)."""
    h = jax.nn.gelu(x @ wi.astype(x.dtype) + bi.astype(x.dtype))
    return h @ wo.astype(x.dtype) + bo.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(dh: int, base: float = 10000.0):
    return 1.0 / (base ** (np.arange(0, dh, 2, dtype=np.float32) / dh))


def apply_rope(x, positions, base: float = 10000.0):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, base))            # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    angles = angles[..., None, :]                         # (..., S, 1, dh/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None, z_loss: float = 0.0):
    """Mean next-token cross-entropy; logits may be vocab-sharded (GSPMD
    inserts the reductions).  ``mask`` is 1 for counted positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(loss)
    mask = mask.astype(jnp.float32)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
