"""Model substrate: the 10 assigned architectures as composable blocks.

Families: dense GQA transformers (tinyllama, deepseek-7b, qwen2-1.5b/72b),
MoE (dbrx, deepseek-v2 with MLA), audio decoder (musicgen), VLM with
cross-attention (llama-3.2-vision), hybrid recurrent (recurrentgemma
RG-LRU + local attention), and xLSTM (sLSTM/mLSTM).

Everything is functional JAX: params are dict pytrees with layer-stacked
leaves, forward passes ``lax.scan`` over homogeneous layer segments (so
a 100-layer model lowers to a small HLO), and sharding is expressed as
PartitionSpec trees computed from logical axis rules (DESIGN.md Sec. 4).
"""

from repro.models.model import Model, ModelConfig, build_model

__all__ = ["Model", "ModelConfig", "build_model"]
