"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory), after
arXiv:2405.04517.  The assigned xlstm-350m config has d_ff = 0: the
"MLP" lives inside the blocks themselves (mLSTM up/down projection
factor 2; sLSTM with a 4/3 gated MLP after the cell).

mLSTM is evaluated *chunkwise* for training/prefill: within a chunk the
quadratic (attention-like) form, across chunks a recurrence on the
(nh, dh, dh) matrix memory — linear in sequence length, which is why
this arch runs the ``long_500k`` shape.  Decode carries (C, n, m) per
layer.  sLSTM has a genuine sequential dependency through its recurrent
weights R (the xLSTM paper notes it is not parallelizable); we evaluate
it with ``lax.scan`` over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as bl


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def init_mlstm(key, d, nh, pf: float = 2.0, conv_width: int = 4):
    pd = int(d * pf)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "w_up": bl.dense_init(ks[0], (d, pd)),
        "w_gate": bl.dense_init(ks[1], (d, pd)),
        "conv": bl.dense_init(ks[2], (conv_width, pd)) * 0.1,
        "wq": bl.dense_init(ks[3], (pd, pd)),
        "wk": bl.dense_init(ks[4], (pd, pd)),
        "wv": bl.dense_init(ks[5], (pd, pd)),
        "wif": bl.dense_init(ks[6], (pd, 2 * nh)),   # input & forget gates
        "gn": jnp.ones((pd,), jnp.float32),          # group norm scale
        "w_down": bl.dense_init(ks[7], (pd, d)),
    }


def _chunk_mlstm(q, k, v, logf, logi, chunk: int, init=None):
    """Chunkwise-parallel mLSTM. q,k,v: (B,S,nh,dh); logf/logi: (B,S,nh).

    Returns (h (B,S,nh,dh), final_state (C, n, m)).  Stabilization: we
    subtract the per-sequence input-gate max M = max_s logi (per
    batch/head) from every i weight and floor the denominator at
    exp(-M) — a whole-sequence variant of the paper's running-max m_t
    (documented fidelity simplification; the single-step decode path
    implements the exact stabilized recurrence).  All decay weights are
    then <= 1, so no exp can overflow.  ``init``: optional carried
    stabilized state (C0, n0, m0) for chunked prefill continuation.
    """
    B, S, nh, dh = q.shape
    M = jnp.max(logi, axis=1, keepdims=True)          # (B,1,nh)
    if init is not None:
        M = jnp.maximum(M, init[2][:, None])          # include carried m0
    logi = logi - M
    floor = jnp.exp(-M[:, 0])                         # (B,nh)

    nc = S // chunk
    qc = q.reshape(B, nc, chunk, nh, dh)
    kc = k.reshape(B, nc, chunk, nh, dh)
    vc = v.reshape(B, nc, chunk, nh, dh)
    fc = logf.reshape(B, nc, chunk, nh)
    ic = logi.reshape(B, nc, chunk, nh)

    csum_f = jnp.cumsum(fc, axis=2)                   # within-chunk decay
    tot_f = csum_f[:, :, -1]                          # (B,nc,nh)

    # ---- intra-chunk (quadratic with decay mask) --------------------------
    # weight for pair (t, s<=t): exp(csum_f[t] - csum_f[s] + logi[s]) <= 1
    wq_ = csum_f[:, :, :, None, :]                    # (B,nc,T,1,nh)
    ws_ = (csum_f - ic)[:, :, None, :, :]             # (B,nc,1,T,nh)
    logw = wq_ - ws_                                  # (B,nc,T,T,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(mask[None, None, :, :, None], jnp.exp(logw), 0.0)
    scores = jnp.einsum("bcthd,bcshd->bctsh", qc, kc) / jnp.sqrt(dh)
    h_intra = jnp.einsum("bctsh,bctsh,bcshd->bcthd",
                         scores.astype(jnp.float32), w, vc.astype(jnp.float32))
    norm_intra = jnp.einsum("bctsh,bctsh,bcshd->bcthd",
                            scores.astype(jnp.float32), w,
                            jnp.ones_like(vc, jnp.float32))

    # ---- inter-chunk: recurrence over chunk memories ----------------------
    # chunk memory delta: sum_s exp(tot_f - csum_f[s]) i_s k_s v_s^T
    decay_s = jnp.exp((tot_f[:, :, None] - csum_f + ic))      # (B,nc,T,nh)
    dC = jnp.einsum("bcshd,bcsh,bcshe->bchde", kc.astype(jnp.float32),
                    decay_s, vc.astype(jnp.float32))
    dn = jnp.einsum("bcshd,bcsh->bchd", kc.astype(jnp.float32), decay_s)

    def combine(l, r):
        fl, Cl, nl = l
        fr, Cr, nr = r
        return fl + fr, Cr + jnp.exp(fr)[..., None, None] * Cl, nr + jnp.exp(fr)[..., None] * nl

    f_tot = jnp.moveaxis(tot_f, 1, 0)                 # (nc,B,nh)
    C_all = jnp.moveaxis(dC, 1, 0)                    # (nc,B,nh,dh,dh)
    n_all = jnp.moveaxis(dn, 1, 0)                    # (nc,B,nh,dh)
    f_pre, C_pre, n_pre = jax.lax.associative_scan(
        combine, (f_tot, C_all, n_all))
    # memory *before* chunk c = scanned value of chunk c-1; shift by one
    C_prev = jnp.concatenate([jnp.zeros_like(C_pre[:1]), C_pre[:-1]])
    n_prev = jnp.concatenate([jnp.zeros_like(n_pre[:1]), n_pre[:-1]])
    if init is not None:
        # carried state contributes exp(prefix_f + m0 - M) * (C0, n0)
        C0, n0, m0 = init
        prefix_f = jnp.concatenate([jnp.zeros_like(f_pre[:1]), f_pre[:-1]])
        w0 = jnp.exp(prefix_f + (m0 - M[:, 0])[None])          # (nc,B,nh)
        C_prev = C_prev + w0[..., None, None] * C0.astype(jnp.float32)[None]
        n_prev = n_prev + w0[..., None] * n0.astype(jnp.float32)[None]
    C_prev = jnp.moveaxis(C_prev, 0, 1)               # (B,nc,nh,dh,dh)
    n_prev = jnp.moveaxis(n_prev, 0, 1)
    # final carried state (stabilized at scale exp(-M))
    C_T = C_pre[-1]
    n_T = n_pre[-1]
    if init is not None:
        wT = jnp.exp(f_pre[-1] + (m0 - M[:, 0]))
        C_T = C_T + wT[..., None, None] * C0.astype(jnp.float32)
        n_T = n_T + wT[..., None] * n0.astype(jnp.float32)
    final = (C_T, n_T, M[:, 0])

    # contribution of carried memory at step t: exp(csum_f[t]) q_t . C_prev
    decay_t = jnp.exp(csum_f)                         # (B,nc,T,nh)
    h_inter = jnp.einsum("bcthd,bchde,bcth->bcthe",
                         qc.astype(jnp.float32), C_prev, decay_t) / jnp.sqrt(dh)
    norm_inter = jnp.einsum("bcthd,bchd,bcth->bcth",
                            qc.astype(jnp.float32), n_prev, decay_t)[..., None] / jnp.sqrt(dh)

    h = h_intra + h_inter
    norm = jnp.abs(norm_intra + norm_inter)
    # denominator floor exp(-M): the stabilized max(|n^T q|, exp(-m)) form
    floor_b = floor.reshape(B, 1, 1, nh, 1)
    h = h / jnp.maximum(norm, floor_b)
    return h.reshape(B, S, nh, dh).astype(q.dtype), final


def mlstm_block(p, x, *, nh, chunk: int = 64, state=None):
    """x: (B,S,d) -> (B,S,d).  ``state`` (decode): dict C (B,nh,dh,dh),
    n (B,nh,dh), conv (B,W-1,pd)."""
    B, S, d = x.shape
    xi = bl.rms_norm(x, p["ln"])
    up = xi @ p["w_up"].astype(x.dtype)
    gate = jax.nn.silu(xi @ p["w_gate"].astype(x.dtype))
    pd = up.shape[-1]
    dh = pd // nh

    conv_state = None if state is None else state["conv"]
    from repro.models.recurrent import _conv1d_causal
    xc, new_conv = _conv1d_causal(up, p["conv"], conv_state)
    xc = jax.nn.silu(xc)

    q = (xc @ p["wq"].astype(x.dtype)).reshape(B, S, nh, dh)
    k = (xc @ p["wk"].astype(x.dtype)).reshape(B, S, nh, dh)
    v = (up @ p["wv"].astype(x.dtype)).reshape(B, S, nh, dh)
    gates = (xc @ p["wif"].astype(x.dtype)).astype(jnp.float32)
    logi, logf = gates[..., :nh], jax.nn.log_sigmoid(gates[..., nh:])

    if state is None or S > 1:
        init = None
        if state is not None:
            init = (state["C"].astype(jnp.float32),
                    state["n"].astype(jnp.float32),
                    state["m"].astype(jnp.float32))
        if S % chunk:  # pad to a chunk multiple (pad logf=0 => f=1 no-op decay)
            pad = chunk - S % chunk
            padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
            h, fin = _chunk_mlstm(padf(q), padf(k), padf(v), padf(logf),
                                  padf(logi) - 1e9 * (jnp.arange(S + pad) >= S)[None, :, None],
                                  chunk, init=init)
            h = h[:, :S]
        else:
            h, fin = _chunk_mlstm(q, k, v, logf, logi, chunk, init=init)
        if state is None:
            new_state = None
        else:
            new_state = {"C": fin[0], "n": fin[1], "m": fin[2],
                         "conv": new_conv}
    else:
        # exact stabilized single-step recurrence (xLSTM paper, eq. 15/25)
        C0 = state["C"].astype(jnp.float32)
        n0 = state["n"].astype(jnp.float32)
        m0 = state["m"].astype(jnp.float32)
        lf, li = logf[:, 0], logi[:, 0]                # (B,nh)
        m = jnp.maximum(lf + m0, li)
        f = jnp.exp(lf + m0 - m)
        i = jnp.exp(li - m)
        kv = jnp.einsum("bhd,bhe->bhde", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        C = f[..., None, None] * C0 + i[..., None, None] * kv
        n = f[..., None] * n0 + i[..., None] * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), C) / jnp.sqrt(dh)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, 0].astype(jnp.float32), n)) / jnp.sqrt(dh)
        den = jnp.maximum(den, jnp.exp(-m))[..., None]
        h = (num / den)[:, None].astype(x.dtype)
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}

    h = h.reshape(B, S, pd)
    h = bl.rms_norm(h, p["gn"]) * gate
    return x + h @ p["w_down"].astype(x.dtype), new_state


def make_mlstm_state(B, d, nh, pf: float = 2.0, conv_width: int = 4):
    pd = int(d * pf)
    dh = pd // nh
    return {
        "C": jnp.zeros((B, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((B, nh, dh), jnp.float32),
        "m": jnp.full((B, nh), -30.0, jnp.float32),
        "conv": jnp.zeros((B, conv_width - 1, pd), jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def init_slstm(key, d, nh, mlp_pf: float = 4.0 / 3.0):
    dh = d // nh
    ks = jax.random.split(key, 7)
    f = int(d * mlp_pf)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "w": bl.dense_init(ks[0], (d, 4 * d)),            # i,f,z,o pre-acts
        "r": bl.dense_init(ks[1], (nh, dh, 4 * dh)) * 0.5,  # block-diag recurrent
        "gn": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "wg": bl.dense_init(ks[2], (d, f)),
        "wu": bl.dense_init(ks[3], (d, f)),
        "wd": bl.dense_init(ks[4], (f, d)),
    }


def slstm_block(p, x, *, nh, state=None):
    """Sequential sLSTM with exponential gating and block-diagonal
    recurrence.  state: dict h,c,n,m each (B,d)."""
    B, S, d = x.shape
    dh = d // nh
    xi = bl.rms_norm(x, p["ln"])
    pre = (xi @ p["w"].astype(x.dtype)).astype(jnp.float32)  # (B,S,4d)

    if state is None:
        h0 = jnp.zeros((B, d), jnp.float32)
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
    else:
        h0, c0, n0, m0 = (state[k].astype(jnp.float32) for k in ("h", "c", "n", "m"))

    r = p["r"].astype(jnp.float32)

    def step(carry, pre_t):
        h, c, n, m = carry
        hh = h.reshape(B, nh, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, 4 * d)
        zifo = pre_t + rec
        zi, zf, zz, zo = jnp.split(zifo, 4, axis=-1)
        log_i = zi
        log_f = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(log_f + m, log_i)
        i = jnp.exp(log_i - m_new)
        f = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(zz)
        o = jax.nn.sigmoid(zo)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    (hT, cT, nT, mT), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                        jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)        # (B,S,d)
    h = bl.rms_norm(h, p["gn"])
    y = x + h
    # gated MLP (the block's own FFN; config d_ff = 0)
    yi = bl.rms_norm(y, p["ln2"])
    y = y + bl.swiglu(yi, p["wg"], p["wu"], p["wd"])
    new_state = {"h": hT, "c": cT, "n": nT, "m": mT}
    return y, new_state


def make_slstm_state(B, d):
    return {
        "h": jnp.zeros((B, d), jnp.float32),
        "c": jnp.zeros((B, d), jnp.float32),
        "n": jnp.zeros((B, d), jnp.float32),
        "m": jnp.full((B, d), -1e30, jnp.float32),
    }
