"""Attention variants: GQA/MHA (+QKV bias), local windowed, cross-attention,
and DeepSeek-V2 MLA (multi-head latent attention) with absorbed-decode.

Shape conventions: activations (B, S, d); heads H, kv-heads K, head dim
``dh``; caches carry absolute slot positions so sliding-window decode can
use a ring buffer of ``window`` slots instead of the full sequence.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as bl


# --------------------------------------------------------------------------
# masked softmax attention core
# --------------------------------------------------------------------------

def _attend(q, k, v, q_pos, k_pos, *, causal=True, window=0, logit_cap=0.0):
    """q: (B,S,K,G,dh) k/v: (B,T,K,dh); positions give masking.

    Returns (B,S,K,G,dh).  Slots with k_pos < 0 are invalid (unwritten
    ring-buffer slots).
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(dh)
    if logit_cap:
        scores = logit_cap * jnp.tanh(scores / logit_cap)
    mask = (k_pos[:, None, :] >= 0)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", probs, v)


# --------------------------------------------------------------------------
# GQA (covers MHA when K == H and MQA when K == 1)
# --------------------------------------------------------------------------

def init_gqa(key, d, H, K, dh, bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": bl.dense_init(ks[0], (d, H * dh)),
        "wk": bl.dense_init(ks[1], (d, K * dh)),
        "wv": bl.dense_init(ks[2], (d, K * dh)),
        "wo": bl.dense_init(ks[3], (H * dh, d)),
    }
    if bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((K * dh,), jnp.float32)
        p["bv"] = jnp.zeros((K * dh,), jnp.float32)
    return p


def make_kv_cache(B, slots, K, dh, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((B, slots, K, dh), dtype),
        "v": jnp.zeros((B, slots, K, dh), dtype),
        "pos": -jnp.ones((B, slots), jnp.int32),
    }


def _ring_write(cache, k_new, v_new, positions):
    """Write S new entries at slots pos % W (S <= W guaranteed by caller)."""
    W = cache["k"].shape[1]
    slots = positions % W                       # (B, S)
    k = _scatter_slots(cache["k"], k_new, slots)
    v = _scatter_slots(cache["v"], v_new, slots)
    pos = jax.vmap(lambda p, s, n: p.at[s].set(n))(cache["pos"], slots, positions)
    return {"k": k, "v": v, "pos": pos}


def _scatter_slots(buf, new, slots):
    # buf (B,W,K,dh), new (B,S,K,dh), slots (B,S)
    return jax.vmap(lambda b, n, s: b.at[s].set(n))(buf, new, slots)


def gqa(params, x, positions, *, H, K, dh, causal=True, window=0,
        rope_base=10000.0, cache=None, logit_cap=0.0, ring_ctx=None):
    """Full GQA layer: qkv proj -> rope -> attend -> out proj.

    ``positions``: (B, S) absolute positions of x.
    ``cache``: None for self-contained (training) attention, else a ring
    cache dict; returns (out, new_cache).
    ``ring_ctx``: (mesh, seq_axis, dp_axes) — sequence-parallel exact
    ring attention for long prefill/train (cfg.seq_shard); assumes the
    attention context is exactly x (fresh-prefill or training).
    """
    B, S, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, K, H // K, dh)
    k = k.reshape(B, S, K, dh)
    v = v.reshape(B, S, K, dh)
    q = bl.apply_rope(q.reshape(B, S, K * (H // K), dh), positions, rope_base)
    q = q.reshape(B, S, K, H // K, dh)
    k = bl.apply_rope(k, positions, rope_base)

    if ring_ctx is not None and S > 1 and causal and not window:
        from repro.models.ring_attention import ring_attention
        mesh, seq_axis, dp_axes = ring_ctx
        out = ring_attention(mesh, seq_axis, dp_axes, q, k, v, positions)
        new_cache = None
        if cache is not None:  # prefill: still record k/v for decode
            W = cache["k"].shape[1]
            if S > W:
                kw, vw, pw = k[:, -W:], v[:, -W:], positions[:, -W:]
            else:
                kw, vw, pw = k, v, positions
            new_cache = _ring_write(cache, kw.astype(cache["k"].dtype),
                                    vw.astype(cache["v"].dtype), pw)
        out = out.reshape(B, S, H * dh)
        return out @ params["wo"].astype(x.dtype), new_cache

    if cache is None:
        out = _attend(q, k, v, positions, positions, causal=causal,
                      window=window, logit_cap=logit_cap)
        new_cache = None
    else:
        W = cache["k"].shape[1]
        if S > W:  # prefill longer than the ring: only the last W matter
            kw, vw, pw = k[:, -W:], v[:, -W:], positions[:, -W:]
        else:
            kw, vw, pw = k, v, positions
        new_cache = _ring_write(cache, kw.astype(cache["k"].dtype),
                                vw.astype(cache["v"].dtype), pw)
        out = _attend(q, new_cache["k"].astype(q.dtype),
                      new_cache["v"].astype(q.dtype), positions,
                      new_cache["pos"], causal=causal, window=window,
                      logit_cap=logit_cap)
    out = out.reshape(B, S, H * dh)
    return out @ params["wo"].astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# cross-attention (llama-3.2-vision image layers)
# --------------------------------------------------------------------------

def init_cross(key, d, H, K, dh):
    ks = jax.random.split(key, 5)
    return {
        "wq": bl.dense_init(ks[0], (d, H * dh)),
        "wk": bl.dense_init(ks[1], (d, K * dh)),
        "wv": bl.dense_init(ks[2], (d, K * dh)),
        "wo": bl.dense_init(ks[3], (H * dh, d)),
        "gate": jnp.zeros((), jnp.float32),   # tanh-gated, starts closed
        "kln": jnp.ones((dh,), jnp.float32),
        "qln": jnp.ones((dh,), jnp.float32),
    }


def cross_attention(params, x, kv_feats, *, H, K, dh):
    """q from text stream, k/v from (precomputed) image patch embeddings
    (B, N, d); no causality, no rope (positions are in the patches)."""
    B, S, _ = x.shape
    N = kv_feats.shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, K, H // K, dh)
    k = (kv_feats.astype(x.dtype) @ params["wk"].astype(x.dtype)).reshape(B, N, K, dh)
    v = (kv_feats.astype(x.dtype) @ params["wv"].astype(x.dtype)).reshape(B, N, K, dh)
    q = bl.rms_norm(q, params["qln"])
    k = bl.rms_norm(k, params["kln"])
    q_pos = jnp.zeros((B, S), jnp.int32)
    k_pos = jnp.zeros((B, N), jnp.int32)
    out = _attend(q, k, v, q_pos, k_pos, causal=False)
    out = out.reshape(B, S, H * dh) @ params["wo"].astype(x.dtype)
    return jnp.tanh(params["gate"]).astype(x.dtype) * out


# --------------------------------------------------------------------------
# DeepSeek-V2 MLA
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLADims:
    q_lora: int = 1536
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128


def init_mla(key, d, H, dims: MLADims):
    ks = jax.random.split(key, 8)
    return {
        "wdq": bl.dense_init(ks[0], (d, dims.q_lora)),
        "qln": jnp.ones((dims.q_lora,), jnp.float32),
        "wuq": bl.dense_init(ks[1], (dims.q_lora, H * (dims.dh_nope + dims.dh_rope))),
        "wdkv": bl.dense_init(ks[2], (d, dims.kv_lora)),
        "kvln": jnp.ones((dims.kv_lora,), jnp.float32),
        "wkr": bl.dense_init(ks[3], (d, dims.dh_rope)),
        "wuk": bl.dense_init(ks[4], (dims.kv_lora, H * dims.dh_nope)),
        "wuv": bl.dense_init(ks[5], (dims.kv_lora, H * dims.dh_v)),
        "wo": bl.dense_init(ks[6], (H * dims.dh_v, d)),
    }


def make_mla_cache(B, slots, dims: MLADims, dtype=jnp.bfloat16):
    """MLA caches the *latent* c_kv + shared rope key: (kv_lora + dh_rope)
    words/token vs 2*K*dh for GQA — the paper-config's memory saving."""
    return {
        "ckv": jnp.zeros((B, slots, dims.kv_lora), dtype),
        "kr": jnp.zeros((B, slots, dims.dh_rope), dtype),
        "pos": -jnp.ones((B, slots), jnp.int32),
    }


def _mla_qkr(params, x, positions, H, dims):
    B, S, _ = x.shape
    cq = bl.rms_norm(x @ params["wdq"].astype(x.dtype), params["qln"])
    q = (cq @ params["wuq"].astype(x.dtype)).reshape(B, S, H, dims.dh_nope + dims.dh_rope)
    q_nope, q_rope = q[..., :dims.dh_nope], q[..., dims.dh_nope:]
    q_rope = bl.apply_rope(q_rope, positions)
    kr = bl.apply_rope((x @ params["wkr"].astype(x.dtype))[:, :, None, :], positions)[:, :, 0]
    ckv = bl.rms_norm(x @ params["wdkv"].astype(x.dtype), params["kvln"])
    return q_nope, q_rope, ckv, kr


def mla(params, x, positions, *, H, dims: MLADims, cache=None):
    """Training/prefill form (materialized per-head k,v) and absorbed
    decode form (scores in latent space; the DeepSeek-V2 inference trick)
    selected by whether a cache is provided and S == 1."""
    B, S, _ = x.shape
    q_nope, q_rope, ckv, kr = _mla_qkr(params, x, positions, H, dims)

    if cache is not None:
        W = cache["ckv"].shape[1]
        if S > W:
            ckv_w, kr_w, pw = ckv[:, -W:], kr[:, -W:], positions[:, -W:]
        else:
            ckv_w, kr_w, pw = ckv, kr, positions
        slots = pw % W
        cache = {
            "ckv": _scatter2(cache["ckv"], ckv_w.astype(cache["ckv"].dtype), slots),
            "kr": _scatter2(cache["kr"], kr_w.astype(cache["kr"].dtype), slots),
            "pos": jax.vmap(lambda p, s, n: p.at[s].set(n))(cache["pos"], slots, pw),
        }
        ckv_all = cache["ckv"].astype(x.dtype)
        kr_all = cache["kr"].astype(x.dtype)
        k_pos = cache["pos"]
    else:
        ckv_all, kr_all, k_pos = ckv, kr, positions

    if cache is not None and S == 1:
        # absorbed decode: q_c = q_nope @ W_uk^T  (per head, into latent)
        wuk = params["wuk"].astype(x.dtype).reshape(dims.kv_lora, H, dims.dh_nope)
        q_c = jnp.einsum("bshn,chn->bshc", q_nope, wuk)
        s_c = jnp.einsum("bshc,btc->bhst", q_c, ckv_all)
        s_r = jnp.einsum("bshn,btn->bhst", q_rope, kr_all)
        scores = (s_c + s_r).astype(jnp.float32) / np.sqrt(dims.dh_nope + dims.dh_rope)
        mask = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= positions[:, :, None])
        scores = jnp.where(mask[:, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_c = jnp.einsum("bhst,btc->bshc", probs, ckv_all)     # latent output
        wuv = params["wuv"].astype(x.dtype).reshape(dims.kv_lora, H, dims.dh_v)
        out = jnp.einsum("bshc,chv->bshv", o_c, wuv)
    else:
        T = ckv_all.shape[1]
        k_nope = (ckv_all @ params["wuk"].astype(x.dtype)).reshape(B, T, H, dims.dh_nope)
        v = (ckv_all @ params["wuv"].astype(x.dtype)).reshape(B, T, H, dims.dh_v)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None], (B, T, H, dims.dh_rope))], -1)
        # K = H, G = 1 layout for the shared attention core
        q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None, :]  # (B,S,H,1,dh)
        out = _attend(q, k, v, positions, k_pos, causal=True)
        out = out.reshape(B, S, H, dims.dh_v)

    out = out.reshape(B, S, H * dims.dh_v)
    return out @ params["wo"].astype(x.dtype), cache


def _scatter2(buf, new, slots):
    # buf (B,W,C), new (B,S,C), slots (B,S)
    return jax.vmap(lambda b, n, s: b.at[s].set(n))(buf, new, slots)
