"""Ring attention: sequence-parallel exact attention on Shoal puts.

For long-context prefill the baseline sharding (heads over ``model``)
all-gathers K/V per layer and materializes O(S^2 / tp) score blocks.
Ring attention shards the *sequence* over the model axis instead: each
device owns an S/n slice of q, k, v; K/V blocks then rotate around the
ring — one ``lax.ppermute`` hop per step, i.e. exactly a Shoal one-sided
neighbor put (DESIGN.md: collective-permute *is* the AM Long put on
ICI) — while each device accumulates online-softmax partials for its
q slice.  n-1 hops of S/n-sized blocks replace the all-gathers, memory
falls from O(S^2) to O((S/n)^2) per step, and weights stay replicated
(this mode targets models whose weights fit per-device, cfg.tp=False).

This is the paper's technique applied where the paper could not go: the
same one-sided-put primitive, scheduled as a software systolic ring over
a pod.  Numerically exact (tested against the oracle); fully manual
shard_map so every collective is explicit and f32-safe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.runtime.jax_compat import shard_map


def _block_attend(q, k, v, q_pos, k_pos, scale):
    """Partial attention of a q block against one k/v block.

    q: (B,Sq,K,G,dh) k,v: (B,Sk,K,dh); returns (num (B,Sq,K,G,dh),
    denom (B,Sq,K,G), m (B,Sq,K,G)) in f32.
    """
    s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    mask = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    s = jnp.where(mask[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)                                  # (B,K,G,Sq)
    p = jnp.exp(s - m[..., None])
    denom = jnp.sum(p, axis=-1)
    num = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v).astype(jnp.float32)
    # reorder m, denom to (B,Sq,K,G)
    m = jnp.moveaxis(m, 3, 1)
    denom = jnp.moveaxis(denom, 3, 1)
    return num, denom, m


def ring_attention_local(q, k, v, q_pos, k_pos, *, axis: str, n: int,
                         scale: float):
    """Per-device body (inside fully-manual shard_map over ``axis``).

    q: (B,Sq,K,G,dh) local slice; k,v: (B,Sk,K,dh) local slice;
    q_pos/k_pos: (B,Sq)/(B,Sk) absolute positions (-1 = invalid).
    Returns (B,Sq,K,G,dh) exact causal attention output.
    """
    B, Sq, K, G, dh = q.shape
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(carry, _):
        k_cur, kp_cur, num, den, m = carry
        n_new, d_new, m_new = _block_attend(q, k_cur[0], k_cur[1], q_pos,
                                            kp_cur, scale)
        m_tot = jnp.maximum(m, m_new)
        a_old = jnp.exp(m - m_tot)
        a_new = jnp.exp(m_new - m_tot)
        num = num * a_old[..., None] + n_new * a_new[..., None]
        den = den * a_old + d_new * a_new
        # rotate the K/V block one hop around the ring (one-sided put)
        k_nxt = (lax.ppermute(k_cur[0], axis, perm),
                 lax.ppermute(k_cur[1], axis, perm))
        kp_nxt = lax.ppermute(kp_cur, axis, perm)
        return (k_nxt, kp_nxt, num, den, m_tot), ()

    num0 = jnp.zeros((B, Sq, K, G, dh), jnp.float32)
    den0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    m0 = jnp.full((B, Sq, K, G), -1e30, jnp.float32)
    (_, _, num, den, _), _ = lax.scan(
        step, ((k, v), k_pos, num0, den0, m0), None, length=n)
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)


def ring_attention(mesh, seq_axis: str, dp_axes: tuple, q, k, v, positions,
                   *, scale: float | None = None):
    """Global entry: q (B,S,K,G,dh), k/v (B,S,K,dh), positions (B,S); S
    sharded over ``seq_axis``, batch over ``dp_axes``.  Exact causal
    attention, O(S/n) resident K/V per device."""
    n = mesh.shape[seq_axis]
    dh = q.shape[-1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(dh))

    fn = functools.partial(ring_attention_local, axis=seq_axis, n=n,
                           scale=scale)
    qspec = P(dp_axes, seq_axis, None, None, None)
    kspec = P(dp_axes, seq_axis, None, None)
    pspec = P(dp_axes, seq_axis)
    return shard_map(fn, mesh=mesh,
                         in_specs=(qspec, kspec, kspec, pspec, pspec),
                         out_specs=qspec, check_vma=False)(
        q, k, v, positions, positions)
